//! Deterministic synthetic "world": entities with attributes and relations.
//!
//! The paper's pipeline needs (a) a pre-training corpus, (b) an instruct
//! fine-tuning mixture, and (c) held-out zero-shot benchmarks whose answers
//! the fine-tuned model knows better than the base model. Offline we cannot
//! use C4/ARC/HellaSwag/PIQA/Winogrande, so we generate a seeded world of
//! entities/facts; the base corpus states facts declaratively, the instruct
//! mixture teaches a Q/A format over a *subset* of facts, and the eval items
//! query the held-out subset (same format, unseen instances) — reproducing
//! the base→instruct accuracy gap that the weight deltas encode.

use crate::util::rng::Rng;

pub const COLORS: [&str; 6] = ["red", "blue", "green", "gold", "black", "white"];
pub const PLACES: [&str; 6] = ["rome", "york", "kiev", "oslo", "cairo", "quito"];
pub const CRAFTS: [&str; 6] = ["baker", "smith", "scribe", "weaver", "potter", "fisher"];
pub const ITEMS: [&str; 6] = ["book", "lamp", "coin", "drum", "kite", "harp"];

/// Product made by each craft (drives the continuation task family).
pub const PRODUCTS: [&str; 6] = ["bread", "swords", "letters", "cloth", "vases", "nets"];

#[derive(Clone, Debug)]
pub struct World {
    pub entities: Vec<String>,
    /// Attribute indices per entity (into the const tables above).
    pub color: Vec<usize>,
    pub place: Vec<usize>,
    pub craft: Vec<usize>,
    pub item: Vec<usize>,
    /// `likes[i] = j`: entity i likes entity j (j != i).
    pub likes: Vec<usize>,
}

/// A single atomic fact about the world.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fact {
    Color(usize),
    Place(usize),
    Craft(usize),
    Owns(usize),
    Likes(usize),
}

impl World {
    pub fn generate(seed: u64, n_entities: usize) -> World {
        assert!(n_entities >= 2);
        let mut rng = Rng::new(seed ^ 0x57_4F_52_4C_44); // "WORLD"
        let mut entities = Vec::with_capacity(n_entities);
        let consonants = b"bdfgklmnprstvz";
        let vowels = b"aeiou";
        let mut seen = std::collections::HashSet::new();
        while entities.len() < n_entities {
            let syls = rng.range(2, 4);
            let mut name = String::new();
            for _ in 0..syls {
                name.push(*rng.choice(consonants) as char);
                name.push(*rng.choice(vowels) as char);
            }
            if seen.insert(name.clone()) {
                entities.push(name);
            }
        }
        let n = n_entities;
        let pick = |k: usize, r: &mut Rng| (0..n).map(|_| r.below(k)).collect::<Vec<_>>();
        let color = pick(COLORS.len(), &mut rng);
        let place = pick(PLACES.len(), &mut rng);
        let craft = pick(CRAFTS.len(), &mut rng);
        let item = pick(ITEMS.len(), &mut rng);
        let likes = (0..n)
            .map(|i| {
                let mut j = rng.below(n);
                while j == i {
                    j = rng.below(n);
                }
                j
            })
            .collect();
        World { entities, color, place, craft, item, likes }
    }

    pub fn n(&self) -> usize {
        self.entities.len()
    }

    /// All facts in canonical order.
    pub fn all_facts(&self) -> Vec<Fact> {
        let mut out = Vec::with_capacity(self.n() * 5);
        for e in 0..self.n() {
            out.push(Fact::Color(e));
            out.push(Fact::Place(e));
            out.push(Fact::Craft(e));
            out.push(Fact::Owns(e));
            out.push(Fact::Likes(e));
        }
        out
    }

    /// Train/eval split of a fact: ~70% of facts go to the fine-tuning Q/A
    /// mixture, the rest are reserved for held-out evaluation. Deterministic
    /// in the fact identity.
    pub fn is_train_fact(&self, f: Fact) -> bool {
        let (e, salt) = match f {
            Fact::Color(e) => (e, 11u64),
            Fact::Place(e) => (e, 23),
            Fact::Craft(e) => (e, 37),
            Fact::Owns(e) => (e, 53),
            Fact::Likes(e) => (e, 71),
        };
        let h = (e as u64).wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(salt.wrapping_mul(0xBF58476D1CE4E5B9));
        (h >> 33) % 10 < 7
    }

    /// Declarative rendering (base/pre-training corpus style).
    pub fn render_declarative(&self, f: Fact) -> String {
        match f {
            Fact::Color(e) => format!("the color of {} is {}.", self.entities[e], COLORS[self.color[e]]),
            Fact::Place(e) => format!("{} lives in {}.", self.entities[e], PLACES[self.place[e]]),
            Fact::Craft(e) => format!("{} is a {}.", self.entities[e], CRAFTS[self.craft[e]]),
            Fact::Owns(e) => format!("{} owns a {}.", self.entities[e], ITEMS[self.item[e]]),
            Fact::Likes(e) => {
                format!("{} likes {}.", self.entities[e], self.entities[self.likes[e]])
            }
        }
    }

    /// Question rendering (instruct / eval style). Returns (question, answer).
    pub fn render_qa(&self, f: Fact) -> (String, String) {
        match f {
            Fact::Color(e) => (
                format!("Q: what is the color of {}?", self.entities[e]),
                COLORS[self.color[e]].to_string(),
            ),
            Fact::Place(e) => (
                format!("Q: where does {} live?", self.entities[e]),
                PLACES[self.place[e]].to_string(),
            ),
            Fact::Craft(e) => (
                format!("Q: what is the craft of {}?", self.entities[e]),
                CRAFTS[self.craft[e]].to_string(),
            ),
            Fact::Owns(e) => (
                format!("Q: what does {} own?", self.entities[e]),
                ITEMS[self.item[e]].to_string(),
            ),
            Fact::Likes(e) => (
                format!("Q: who does {} like?", self.entities[e]),
                self.entities[self.likes[e]].clone(),
            ),
        }
    }

    /// Distractor answers from the same answer space as the fact.
    pub fn distractors(&self, f: Fact, k: usize, rng: &mut Rng) -> Vec<String> {
        let (pool, correct): (Vec<String>, String) = match f {
            Fact::Color(e) => {
                (COLORS.iter().map(|s| s.to_string()).collect(), COLORS[self.color[e]].into())
            }
            Fact::Place(e) => {
                (PLACES.iter().map(|s| s.to_string()).collect(), PLACES[self.place[e]].into())
            }
            Fact::Craft(e) => {
                (CRAFTS.iter().map(|s| s.to_string()).collect(), CRAFTS[self.craft[e]].into())
            }
            Fact::Owns(e) => {
                (ITEMS.iter().map(|s| s.to_string()).collect(), ITEMS[self.item[e]].into())
            }
            Fact::Likes(e) => (
                self.entities.clone(),
                self.entities[self.likes[e]].clone(),
            ),
        };
        let mut out = Vec::with_capacity(k);
        let mut guard = 0;
        while out.len() < k && guard < 10_000 {
            guard += 1;
            let cand = rng.choice(&pool).clone();
            if cand != correct && !out.contains(&cand) {
                out.push(cand);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn world_is_deterministic() {
        let a = World::generate(5, 30);
        let b = World::generate(5, 30);
        assert_eq!(a.entities, b.entities);
        assert_eq!(a.likes, b.likes);
        let c = World::generate(6, 30);
        assert_ne!(a.entities, c.entities);
    }

    #[test]
    fn names_unique_and_wellformed() {
        let w = World::generate(1, 100);
        let mut names = w.entities.clone();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 100);
        for n in &w.entities {
            assert!(n.len() >= 4 && n.len() <= 6, "{n}");
            assert!(n.chars().all(|c| c.is_ascii_lowercase()));
        }
    }

    #[test]
    fn nobody_likes_themselves() {
        let w = World::generate(2, 50);
        for (i, &j) in w.likes.iter().enumerate() {
            assert_ne!(i, j);
        }
    }

    #[test]
    fn split_roughly_70_30_and_deterministic() {
        let w = World::generate(3, 200);
        let facts = w.all_facts();
        let train = facts.iter().filter(|&&f| w.is_train_fact(f)).count();
        let frac = train as f64 / facts.len() as f64;
        assert!((0.6..0.8).contains(&frac), "train fraction {frac}");
        for &f in facts.iter().take(20) {
            assert_eq!(w.is_train_fact(f), w.is_train_fact(f));
        }
    }

    #[test]
    fn qa_answer_matches_declarative() {
        let w = World::generate(4, 20);
        for f in w.all_facts().into_iter().take(25) {
            let decl = w.render_declarative(f);
            let (_q, a) = w.render_qa(f);
            assert!(decl.contains(&a), "decl '{decl}' should contain answer '{a}'");
        }
    }

    #[test]
    fn distractors_exclude_correct() {
        let w = World::generate(7, 20);
        let mut rng = Rng::new(1);
        for f in w.all_facts().into_iter().take(25) {
            let (_, a) = w.render_qa(f);
            let d = w.distractors(f, 3, &mut rng);
            assert_eq!(d.len(), 3);
            assert!(!d.contains(&a));
            let mut dd = d.clone();
            dd.dedup();
            assert_eq!(dd.len(), 3);
        }
    }
}
