//! Synthetic data substrate: a seeded world of entities/facts, base and
//! instruct corpora, calibration samples, byte-level tokenization, batching,
//! and the five zero-shot MC task families (ARC/HellaSwag/PIQA/Winogrande
//! analogs). See DESIGN.md "Substitutions".

pub mod corpus;
pub mod tasks;
pub mod world;

pub use tasks::{McItem, TaskFamily};
pub use world::World;
