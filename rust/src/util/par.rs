//! Data-parallel helpers built on `std::thread::scope`.
//!
//! `rayon` is unavailable offline. The hot paths in this codebase (delta
//! apply, matmul, calibration solves) are all chunked loops over row ranges,
//! so a scoped fork-join over contiguous ranges is both simple and fast.
//! Thread count defaults to the machine parallelism, clamped by work size so
//! tiny inputs stay single-threaded (spawn overhead ~10s of µs).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use for `n_items` of work where each item is
/// worth roughly `min_per_thread` items of sequential throughput.
pub fn thread_count(n_items: usize, min_per_thread: usize) -> usize {
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let by_work = n_items / min_per_thread.max(1);
    hw.min(by_work.max(1))
}

/// Run `f(start, end)` over disjoint contiguous subranges of `0..n` in
/// parallel. `f` must be `Sync` (called concurrently by several threads).
pub fn parallel_ranges<F>(n: usize, min_per_thread: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = thread_count(n, min_per_thread);
    if threads <= 1 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let fref = &f;
            s.spawn(move || fref(lo, hi));
        }
    });
}

/// Parallel for over mutable row-chunks of a flat buffer: splits `data`
/// (logically `n_rows` rows of `row_len`) into contiguous row ranges and
/// hands each thread its disjoint `&mut [f32]` slice.
pub fn parallel_rows_mut<T: Send, F>(
    data: &mut [T],
    n_rows: usize,
    row_len: usize,
    min_rows_per_thread: usize,
    f: F,
) where
    F: Fn(usize, &mut [T]) + Sync,
{
    assert_eq!(data.len(), n_rows * row_len, "buffer/row shape mismatch");
    let threads = thread_count(n_rows, min_rows_per_thread);
    if threads <= 1 || n_rows == 0 {
        f(0, data);
        return;
    }
    let rows_per = n_rows.div_ceil(threads);
    std::thread::scope(|s| {
        let mut rest = data;
        let mut row0 = 0usize;
        while row0 < n_rows {
            let take_rows = rows_per.min(n_rows - row0);
            let (head, tail) = rest.split_at_mut(take_rows * row_len);
            rest = tail;
            let fref = &f;
            let r0 = row0;
            s.spawn(move || fref(r0, head));
            row0 += take_rows;
        }
    });
}

/// Dynamic work distribution: threads pull item indices from a shared atomic
/// counter. Use when per-item cost is highly variable (e.g. per-module
/// calibration where module shapes differ).
pub fn parallel_items<F>(n: usize, max_threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = thread_count(n, 1).min(max_threads.max(1));
    if threads <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            let fref = &f;
            let nref = &next;
            s.spawn(move || loop {
                let i = nref.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                fref(i);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn ranges_cover_exactly() {
        let hits = AtomicU64::new(0);
        parallel_ranges(1000, 10, |lo, hi| {
            let mut local = 0u64;
            for i in lo..hi {
                local += i as u64;
            }
            hits.fetch_add(local, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 999 * 1000 / 2);
    }

    #[test]
    fn ranges_small_input_single_thread() {
        let hits = AtomicU64::new(0);
        parallel_ranges(3, 100, |lo, hi| {
            hits.fetch_add((hi - lo) as u64, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn rows_mut_disjoint_writes() {
        let n_rows = 97;
        let row_len = 13;
        let mut data = vec![0f32; n_rows * row_len];
        parallel_rows_mut(&mut data, n_rows, row_len, 4, |row0, chunk| {
            for (r, row) in chunk.chunks_mut(row_len).enumerate() {
                for x in row.iter_mut() {
                    *x = (row0 + r) as f32;
                }
            }
        });
        for r in 0..n_rows {
            for c in 0..row_len {
                assert_eq!(data[r * row_len + c], r as f32);
            }
        }
    }

    #[test]
    fn items_process_all_once() {
        let n = 500;
        let flags: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_items(n, 8, |i| {
            flags[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(flags.iter().all(|f| f.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn zero_items_ok() {
        parallel_ranges(0, 1, |_, _| panic!("should not run"));
        parallel_items(0, 8, |_| panic!("should not run"));
    }
}
