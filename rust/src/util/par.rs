//! Data-parallel helpers over the persistent compute pool.
//!
//! `rayon` is unavailable offline. The hot paths in this codebase (delta
//! apply, matmul, calibration solves, batched forwards) are all chunked
//! loops over row ranges, so a fork-join over contiguous ranges is both
//! simple and fast. Work now runs on the process-wide
//! [`pool`](crate::exec::pool) instead of per-call scoped threads: at
//! serving granularity (one GEMM per module per window) the old spawn cost
//! (~10s of µs per call) dominated small matrices.
//!
//! Thread count defaults to the pool's configured width
//! (`PAWD_COMPUTE_THREADS` or the machine parallelism, clamped per thread
//! by [`pool::with_thread_limit`]), and is further clamped by work size so
//! tiny inputs stay single-threaded. Chunks never split a single
//! reduction, so parallel results stay bitwise-equal to serial ones.

use crate::exec::pool;

/// Number of worker threads to use for `n_items` of work where each item is
/// worth roughly `min_per_thread` items of sequential throughput.
pub fn thread_count(n_items: usize, min_per_thread: usize) -> usize {
    let cap = pool::current_threads();
    let by_work = n_items / min_per_thread.max(1);
    cap.min(by_work.max(1))
}

/// Run `f(start, end)` over disjoint contiguous subranges of `0..n` in
/// parallel. `f` must be `Sync` (called concurrently by several threads).
pub fn parallel_ranges<F>(n: usize, min_per_thread: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = thread_count(n, min_per_thread);
    if threads <= 1 {
        f(0, n);
        return;
    }
    pool::global().run(n, threads, min_per_thread, f);
}

/// A `Send + Sync` wrapper for a raw mutable pointer, for parallel loops
/// that hand disjoint sub-slices of one buffer to different threads.
/// Callers are responsible for disjointness of the ranges they touch.
#[derive(Clone, Copy)]
pub struct SendMutPtr<T>(pub *mut T);

// SAFETY: the wrapper only moves the pointer across threads; callers must
// only dereference disjoint ranges (the same contract `split_at_mut`
// enforces statically).
unsafe impl<T: Send> Send for SendMutPtr<T> {}
unsafe impl<T: Send> Sync for SendMutPtr<T> {}

/// Parallel for over mutable row-chunks of a flat buffer: splits `data`
/// (logically `n_rows` rows of `row_len`) into contiguous row ranges and
/// hands each thread its disjoint `&mut [T]` slice.
pub fn parallel_rows_mut<T: Send, F>(
    data: &mut [T],
    n_rows: usize,
    row_len: usize,
    min_rows_per_thread: usize,
    f: F,
) where
    F: Fn(usize, &mut [T]) + Sync,
{
    assert_eq!(data.len(), n_rows * row_len, "buffer/row shape mismatch");
    let threads = thread_count(n_rows, min_rows_per_thread);
    if threads <= 1 || n_rows == 0 {
        f(0, data);
        return;
    }
    let ptr = SendMutPtr(data.as_mut_ptr());
    pool::global().run(n_rows, threads, min_rows_per_thread, move |row0, row1| {
        // SAFETY: chunks from the pool cover disjoint row ranges of
        // `0..n_rows`, so the reconstructed slices never alias, and the
        // buffer outlives the call (`run` blocks until all chunks finish).
        let chunk = unsafe {
            std::slice::from_raw_parts_mut(ptr.0.add(row0 * row_len), (row1 - row0) * row_len)
        };
        f(row0, chunk);
    });
}

/// Dynamic work distribution: threads pull item indices from a shared
/// cursor. Use when per-item cost is highly variable (e.g. per-module
/// calibration where module shapes differ, or per-sequence attention).
pub fn parallel_items<F>(n: usize, max_threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = thread_count(n, 1).min(max_threads.max(1));
    if threads <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    pool::global().run(n, threads, 1, |lo, hi| {
        for i in lo..hi {
            f(i);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn ranges_cover_exactly() {
        let hits = AtomicU64::new(0);
        parallel_ranges(1000, 10, |lo, hi| {
            let mut local = 0u64;
            for i in lo..hi {
                local += i as u64;
            }
            hits.fetch_add(local, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 999 * 1000 / 2);
    }

    #[test]
    fn ranges_small_input_single_thread() {
        let hits = AtomicU64::new(0);
        parallel_ranges(3, 100, |lo, hi| {
            hits.fetch_add((hi - lo) as u64, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn rows_mut_disjoint_writes() {
        let n_rows = 97;
        let row_len = 13;
        let mut data = vec![0f32; n_rows * row_len];
        parallel_rows_mut(&mut data, n_rows, row_len, 4, |row0, chunk| {
            for (r, row) in chunk.chunks_mut(row_len).enumerate() {
                for x in row.iter_mut() {
                    *x = (row0 + r) as f32;
                }
            }
        });
        for r in 0..n_rows {
            for c in 0..row_len {
                assert_eq!(data[r * row_len + c], r as f32);
            }
        }
    }

    #[test]
    fn rows_mut_respects_forced_width() {
        let n_rows = 64;
        let row_len = 5;
        let mut data = vec![0f32; n_rows * row_len];
        pool::with_thread_limit(4, || {
            parallel_rows_mut(&mut data, n_rows, row_len, 1, |row0, chunk| {
                for (r, row) in chunk.chunks_mut(row_len).enumerate() {
                    for x in row.iter_mut() {
                        *x += (row0 + r) as f32 + 1.0;
                    }
                }
            });
        });
        for r in 0..n_rows {
            for c in 0..row_len {
                assert_eq!(data[r * row_len + c], r as f32 + 1.0, "row {r} col {c}");
            }
        }
    }

    #[test]
    fn items_process_all_once() {
        let n = 500;
        let flags: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_items(n, 8, |i| {
            flags[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(flags.iter().all(|f| f.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn zero_items_ok() {
        parallel_ranges(0, 1, |_, _| panic!("should not run"));
        parallel_items(0, 8, |_| panic!("should not run"));
    }
}
