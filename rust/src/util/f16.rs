//! IEEE-754 binary16 (f16) and bfloat16 codecs.
//!
//! The paper stores scale vectors as FP16 and base weights as BF16; the
//! `half` crate is unavailable offline so the conversions are implemented
//! here. Round-to-nearest-even on encode, exact on decode.

/// Convert an f32 to IEEE binary16 bits, round-to-nearest-even.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // Inf / NaN: keep a quiet NaN payload bit if any mantissa bits set.
        return sign | 0x7C00 | if mant != 0 { 0x0200 } else { 0 };
    }
    // Unbiased exponent rebased for f16 (bias 15 vs 127).
    let e = exp - 127 + 15;
    if e >= 0x1F {
        return sign | 0x7C00; // overflow -> inf
    }
    if e <= 0 {
        // Subnormal or zero in f16.
        if e < -10 {
            return sign; // underflow to signed zero
        }
        // Add implicit leading 1, shift into subnormal position.
        let m = mant | 0x0080_0000;
        let shift = (14 - e) as u32;
        let half_val = m >> shift;
        // round to nearest even
        let rem = m & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let rounded = if rem > halfway || (rem == halfway && (half_val & 1) == 1) {
            half_val + 1
        } else {
            half_val
        };
        return sign | rounded as u16;
    }
    // Normal case: 23 -> 10 mantissa bits with RNE.
    let half_val = ((e as u32) << 10) | (mant >> 13);
    let rem = mant & 0x1FFF;
    let rounded = if rem > 0x1000 || (rem == 0x1000 && (half_val & 1) == 1) {
        half_val + 1 // may carry into exponent; that is correct behaviour
    } else {
        half_val
    };
    sign | rounded as u16
}

/// Convert IEEE binary16 bits to f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x03FF) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign // signed zero
        } else {
            // subnormal: normalize
            let mut e = 0i32;
            let mut m = mant;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x03FF;
            sign | (((127 - 15 + e + 1) as u32) << 23) | (m << 13)
        }
    } else if exp == 0x1F {
        sign | 0x7F80_0000 | (mant << 13) // inf / nan
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// f32 -> bf16 bits, round-to-nearest-even (truncation of low 16 bits + RNE).
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040; // quiet NaN
    }
    let round_bit = 0x0000_8000u32;
    let lower = bits & 0xFFFF;
    let upper = bits >> 16;
    if lower > round_bit || (lower == round_bit && (upper & 1) == 1) {
        (upper + 1) as u16
    } else {
        upper as u16
    }
}

/// bf16 bits -> f32 (exact).
#[inline]
pub fn bf16_bits_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Encode a slice of f32 into little-endian f16 bytes.
pub fn encode_f16_slice(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 2);
    for &x in xs {
        out.extend_from_slice(&f32_to_f16_bits(x).to_le_bytes());
    }
    out
}

/// Decode little-endian f16 bytes into f32.
pub fn decode_f16_slice(bytes: &[u8]) -> Vec<f32> {
    assert!(bytes.len() % 2 == 0, "f16 byte slice must have even length");
    bytes
        .chunks_exact(2)
        .map(|c| f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]])))
        .collect()
}

/// Encode a slice of f32 into little-endian bf16 bytes.
pub fn encode_bf16_slice(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 2);
    for &x in xs {
        out.extend_from_slice(&f32_to_bf16_bits(x).to_le_bytes());
    }
    out
}

/// Decode little-endian bf16 bytes into f32.
pub fn decode_bf16_slice(bytes: &[u8]) -> Vec<f32> {
    assert!(bytes.len() % 2 == 0);
    bytes
        .chunks_exact(2)
        .map(|c| bf16_bits_to_f32(u16::from_le_bytes([c[0], c[1]])))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_exact_values_roundtrip() {
        for &v in &[0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0, 0.25, 1024.0] {
            let h = f32_to_f16_bits(v);
            assert_eq!(f16_bits_to_f32(h), v, "value {v}");
        }
    }

    #[test]
    fn f16_signed_zero() {
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
    }

    #[test]
    fn f16_overflow_to_inf() {
        assert_eq!(f32_to_f16_bits(1.0e6), 0x7C00);
        assert_eq!(f32_to_f16_bits(-1.0e6), 0xFC00);
        assert!(f16_bits_to_f32(0x7C00).is_infinite());
    }

    #[test]
    fn f16_nan_propagates() {
        let h = f32_to_f16_bits(f32::NAN);
        assert!(f16_bits_to_f32(h).is_nan());
    }

    #[test]
    fn f16_subnormals() {
        // Smallest positive f16 subnormal = 2^-24.
        let tiny = 2.0f32.powi(-24);
        let h = f32_to_f16_bits(tiny);
        assert_eq!(h, 0x0001);
        assert_eq!(f16_bits_to_f32(h), tiny);
        // Below half the smallest subnormal underflows to zero.
        assert_eq!(f32_to_f16_bits(2.0f32.powi(-26)), 0x0000);
    }

    #[test]
    fn f16_round_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and 1+2^-10 -> rounds to even (1.0).
        let x = 1.0 + 2.0f32.powi(-11);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(x)), 1.0);
        // 1 + 3*2^-11 is halfway between 1+2^-10 and 1+2^-9 -> rounds to even (1+2^-9).
        let y = 1.0 + 3.0 * 2.0f32.powi(-11);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(y)), 1.0 + 2.0f32.powi(-9));
    }

    #[test]
    fn f16_relative_error_bounded() {
        // RNE guarantees rel err <= 2^-11 for normals.
        let mut r = crate::util::rng::Rng::new(1234);
        for _ in 0..10_000 {
            let v = r.normal_f32(0.0, 10.0);
            let back = f16_bits_to_f32(f32_to_f16_bits(v));
            let rel = ((back - v) / v.abs().max(1e-6)).abs();
            assert!(rel <= 4.9e-4, "v={v} back={back} rel={rel}");
        }
    }

    #[test]
    fn bf16_roundtrip_and_error() {
        for &v in &[0.0f32, 1.0, -2.5, 3.1415926, 1e20, -1e-20] {
            let b = bf16_bits_to_f32(f32_to_bf16_bits(v));
            if v == 0.0 {
                assert_eq!(b, 0.0);
            } else {
                assert!(((b - v) / v).abs() < 0.01, "v={v} b={b}");
            }
        }
        assert!(bf16_bits_to_f32(f32_to_bf16_bits(f32::NAN)).is_nan());
    }

    #[test]
    fn slice_codecs_roundtrip() {
        let xs = vec![0.5f32, -1.25, 3.0, 0.0009765625];
        assert_eq!(decode_f16_slice(&encode_f16_slice(&xs)), xs);
        let bs = vec![1.0f32, -2.0, 0.5];
        assert_eq!(decode_bf16_slice(&encode_bf16_slice(&bs)), bs);
    }
}
