//! Hand-rolled benchmark harness (criterion is unavailable offline).
//!
//! Each `rust/benches/*.rs` target is a `harness = false` binary that uses
//! this module: warmup, repeated timed runs, and a stable one-line report
//! (`name ... mean ± std  p50/p90  [iters]`), plus Markdown table helpers so
//! bench output can be pasted into EXPERIMENTS.md verbatim.

use super::stats::Summary;
use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    /// Per-iteration wall time in seconds.
    pub summary: Summary,
    /// Optional throughput denominator: items (or bytes) processed per iter.
    pub items_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn mean_s(&self) -> f64 {
        self.summary.mean
    }

    pub fn report_line(&self) -> String {
        let s = &self.summary;
        let mut line = format!(
            "{:<44} {:>12} ± {:>10}   p50 {:>10}  p90 {:>10}  ({} iters)",
            self.name,
            fmt_dur(s.mean),
            fmt_dur(s.std),
            fmt_dur(s.p50),
            fmt_dur(s.p90),
            self.iters
        );
        if let Some(items) = self.items_per_iter {
            let rate = items / s.mean;
            line.push_str(&format!("  [{}/s]", fmt_rate(rate)));
        }
        line
    }
}

/// Format seconds human-readably (ns/µs/ms/s).
pub fn fmt_dur(s: f64) -> String {
    if !s.is_finite() {
        return "n/a".into();
    }
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Format a rate (items/s) with SI prefixes.
pub fn fmt_rate(r: f64) -> String {
    if r >= 1e9 {
        format!("{:.2}G", r / 1e9)
    } else if r >= 1e6 {
        format!("{:.2}M", r / 1e6)
    } else if r >= 1e3 {
        format!("{:.2}k", r / 1e3)
    } else {
        format!("{:.1}", r)
    }
}

/// Format a byte count.
pub fn fmt_bytes(b: u64) -> String {
    const KB: f64 = 1024.0;
    let b = b as f64;
    if b >= KB * KB * KB {
        format!("{:.2} GiB", b / (KB * KB * KB))
    } else if b >= KB * KB {
        format!("{:.2} MiB", b / (KB * KB))
    } else if b >= KB {
        format!("{:.2} KiB", b / KB)
    } else {
        format!("{b:.0} B")
    }
}

/// Benchmark runner with warmup and adaptive iteration count.
pub struct Bench {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(200),
            measure: Duration::from_secs(1),
            min_iters: 5,
            max_iters: 10_000,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick-profile configuration for CI / smoke runs (set PAWD_BENCH_FAST=1).
    pub fn from_env() -> Self {
        let mut b = Self::default();
        if std::env::var("PAWD_BENCH_FAST").is_ok() {
            b.warmup = Duration::from_millis(20);
            b.measure = Duration::from_millis(150);
            b.min_iters = 2;
        }
        b
    }

    /// Time `f`, which should perform one full iteration of the workload.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        self.run_with_items(name, None, &mut f)
    }

    /// Time `f` and report `items` per-iteration throughput.
    pub fn run_items<F: FnMut()>(&mut self, name: &str, items: f64, mut f: F) -> &BenchResult {
        self.run_with_items(name, Some(items), &mut f)
    }

    fn run_with_items(
        &mut self,
        name: &str,
        items_per_iter: Option<f64>,
        f: &mut dyn FnMut(),
    ) -> &BenchResult {
        // Warmup.
        let w0 = Instant::now();
        let mut warm_iters = 0usize;
        while w0.elapsed() < self.warmup || warm_iters < 1 {
            f();
            warm_iters += 1;
            if warm_iters >= self.max_iters {
                break;
            }
        }
        // Measure.
        let mut samples = Vec::new();
        let m0 = Instant::now();
        while (m0.elapsed() < self.measure || samples.len() < self.min_iters)
            && samples.len() < self.max_iters
        {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        let res = BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            summary: Summary::of(&samples),
            items_per_iter,
        };
        println!("{}", res.report_line());
        self.results.push(res);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Markdown table printer for paper-style result tables.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self, title: &str) {
        println!("\n### {title}\n");
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s
        };
        println!("{}", fmt_row(&self.headers));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        println!("{sep}");
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
        println!();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_records_samples() {
        let mut b = Bench {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(10),
            min_iters: 3,
            max_iters: 100,
            results: vec![],
        };
        let mut x = 0u64;
        let r = b.run("noop", || {
            x = x.wrapping_add(1);
            std::hint::black_box(x);
        });
        assert!(r.iters >= 3);
        assert!(r.summary.mean >= 0.0);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_dur(2.5e-9), "2.5ns");
        assert_eq!(fmt_dur(2.5e-6), "2.50µs");
        assert_eq!(fmt_dur(2.5e-3), "2.50ms");
        assert_eq!(fmt_dur(2.5), "2.500s");
    }

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    #[should_panic]
    fn table_arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".to_string()]);
    }
}
