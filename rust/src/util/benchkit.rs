//! Hand-rolled benchmark harness (criterion is unavailable offline).
//!
//! Each `rust/benches/*.rs` target is a `harness = false` binary that uses
//! this module: warmup, repeated timed runs, and a stable one-line report
//! (`name ... mean ± std  p50/p90  [iters]`), plus Markdown table helpers so
//! bench output can be pasted into EXPERIMENTS.md verbatim.
//!
//! [`BenchReport`] is the machine-readable side: benches merge their
//! scenario metrics into the JSON file named by `PAWD_BENCH_JSON` (CI
//! writes `BENCH_pr.json` this way) and `pawd bench-diff` compares two such
//! files — that pair is the CI perf-regression gate.

use super::json::{self, Json};
use super::stats::Summary;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    /// Per-iteration wall time in seconds.
    pub summary: Summary,
    /// Optional throughput denominator: items (or bytes) processed per iter.
    pub items_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn mean_s(&self) -> f64 {
        self.summary.mean
    }

    pub fn report_line(&self) -> String {
        let s = &self.summary;
        let mut line = format!(
            "{:<44} {:>12} ± {:>10}   p50 {:>10}  p90 {:>10}  ({} iters)",
            self.name,
            fmt_dur(s.mean),
            fmt_dur(s.std),
            fmt_dur(s.p50),
            fmt_dur(s.p90),
            self.iters
        );
        if let Some(items) = self.items_per_iter {
            let rate = items / s.mean;
            line.push_str(&format!("  [{}/s]", fmt_rate(rate)));
        }
        line
    }
}

/// Format seconds human-readably (ns/µs/ms/s).
pub fn fmt_dur(s: f64) -> String {
    if !s.is_finite() {
        return "n/a".into();
    }
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Format a rate (items/s) with SI prefixes.
pub fn fmt_rate(r: f64) -> String {
    if r >= 1e9 {
        format!("{:.2}G", r / 1e9)
    } else if r >= 1e6 {
        format!("{:.2}M", r / 1e6)
    } else if r >= 1e3 {
        format!("{:.2}k", r / 1e3)
    } else {
        format!("{:.1}", r)
    }
}

/// Format a byte count.
pub fn fmt_bytes(b: u64) -> String {
    const KB: f64 = 1024.0;
    let b = b as f64;
    if b >= KB * KB * KB {
        format!("{:.2} GiB", b / (KB * KB * KB))
    } else if b >= KB * KB {
        format!("{:.2} MiB", b / (KB * KB))
    } else if b >= KB {
        format!("{:.2} KiB", b / KB)
    } else {
        format!("{b:.0} B")
    }
}

/// Benchmark runner with warmup and adaptive iteration count.
pub struct Bench {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(200),
            measure: Duration::from_secs(1),
            min_iters: 5,
            max_iters: 10_000,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick-profile configuration for CI / smoke runs (set PAWD_BENCH_FAST=1).
    pub fn from_env() -> Self {
        let mut b = Self::default();
        if std::env::var("PAWD_BENCH_FAST").is_ok() {
            b.warmup = Duration::from_millis(20);
            b.measure = Duration::from_millis(150);
            b.min_iters = 2;
        }
        b
    }

    /// Time `f`, which should perform one full iteration of the workload.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        self.run_with_items(name, None, &mut f)
    }

    /// Time `f` and report `items` per-iteration throughput.
    pub fn run_items<F: FnMut()>(&mut self, name: &str, items: f64, mut f: F) -> &BenchResult {
        self.run_with_items(name, Some(items), &mut f)
    }

    fn run_with_items(
        &mut self,
        name: &str,
        items_per_iter: Option<f64>,
        f: &mut dyn FnMut(),
    ) -> &BenchResult {
        // Warmup.
        let w0 = Instant::now();
        let mut warm_iters = 0usize;
        while w0.elapsed() < self.warmup || warm_iters < 1 {
            f();
            warm_iters += 1;
            if warm_iters >= self.max_iters {
                break;
            }
        }
        // Measure.
        let mut samples = Vec::new();
        let m0 = Instant::now();
        while (m0.elapsed() < self.measure || samples.len() < self.min_iters)
            && samples.len() < self.max_iters
        {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        let res = BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            summary: Summary::of(&samples),
            items_per_iter,
        };
        println!("{}", res.report_line());
        self.results.push(res);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Machine-readable bench output: a flat `scenario → {metric: value}` map.
///
/// File format (`BENCH_*.json`):
///
/// ```text
/// { "format": 1,
///   "provisional": false,
///   "scenarios": { "bench/scenario": { "req_per_s": 123.0, "p50_us": 40.0 } } }
/// ```
///
/// Metric naming is load-bearing for the gate: names ending in `per_s` are
/// throughput (higher is better) and are the only ones gated; everything
/// else (latency quantiles, ratios) is report-only, because absolute times
/// on shared CI runners are too noisy to gate. `provisional: true` marks a
/// baseline that has not yet been promoted from a real CI run — the diff is
/// printed but never fails.
#[derive(Clone, Debug, Default)]
pub struct BenchReport {
    pub provisional: bool,
    pub scenarios: BTreeMap<String, BTreeMap<String, f64>>,
}

impl BenchReport {
    pub fn new() -> BenchReport {
        BenchReport::default()
    }

    /// Record one scenario's metrics (overwrites a same-named scenario).
    pub fn add(&mut self, scenario: &str, metrics: &[(&str, f64)]) {
        let m = metrics.iter().map(|(k, v)| (k.to_string(), *v)).collect();
        self.scenarios.insert(scenario.to_string(), m);
    }

    pub fn load<P: AsRef<Path>>(path: P) -> Result<BenchReport> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading bench report {}", path.display()))?;
        let j = Json::parse(&text)
            .with_context(|| format!("parsing bench report {}", path.display()))?;
        let provisional = j.get("provisional").and_then(|v| v.as_bool()).unwrap_or(false);
        let mut scenarios = BTreeMap::new();
        if let Some(sc) = j.get("scenarios").and_then(|v| v.as_obj()) {
            for (name, metrics) in sc {
                let mut m = BTreeMap::new();
                if let Some(mo) = metrics.as_obj() {
                    for (k, v) in mo {
                        if let Some(x) = v.as_f64() {
                            m.insert(k.clone(), x);
                        }
                    }
                }
                scenarios.insert(name.clone(), m);
            }
        }
        Ok(BenchReport { provisional, scenarios })
    }

    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        let path = path.as_ref();
        let scenarios: Vec<(&str, Json)> = self
            .scenarios
            .iter()
            .map(|(name, m)| {
                let metrics: Vec<(&str, Json)> =
                    m.iter().map(|(k, v)| (k.as_str(), json::n(*v))).collect();
                (name.as_str(), json::obj(metrics))
            })
            .collect();
        let doc = json::obj(vec![
            ("format", json::n(1.0)),
            ("provisional", Json::Bool(self.provisional)),
            ("scenarios", json::obj(scenarios)),
        ]);
        std::fs::write(path, doc.to_string())
            .with_context(|| format!("writing bench report {}", path.display()))
    }

    /// Merge this report's scenarios into the JSON file at `path`,
    /// creating it if needed (several bench binaries append into one
    /// report file).
    pub fn merge_into<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        let path = path.as_ref();
        let mut merged =
            if path.exists() { BenchReport::load(path)? } else { BenchReport::new() };
        for (k, v) in &self.scenarios {
            merged.scenarios.insert(k.clone(), v.clone());
        }
        merged.save(path)
    }

    /// [`merge_into`](Self::merge_into) the file named by
    /// `PAWD_BENCH_JSON`; a no-op when the variable is unset.
    pub fn flush_env(&self) -> Result<()> {
        match std::env::var("PAWD_BENCH_JSON") {
            Ok(path) => self.merge_into(path),
            Err(_) => Ok(()),
        }
    }
}

/// One metric comparison between two [`BenchReport`]s.
#[derive(Clone, Debug)]
pub struct DiffRow {
    pub scenario: String,
    pub metric: String,
    pub baseline: f64,
    pub current: f64,
    /// Relative change `(current - baseline) / baseline`.
    pub change: f64,
    /// Whether this metric participates in the regression gate
    /// (throughput metrics only — see [`BenchReport`]).
    pub gated: bool,
}

impl DiffRow {
    /// A gated metric that dropped more than `max_regression` (e.g. `0.20`
    /// = 20% throughput loss).
    pub fn regressed(&self, max_regression: f64) -> bool {
        self.gated && self.change < -max_regression
    }
}

/// Result of comparing a current report against a baseline.
#[derive(Clone, Debug, Default)]
pub struct BenchDiff {
    pub rows: Vec<DiffRow>,
    /// Scenarios present in the baseline but missing from the current run
    /// (bench coverage regressed — the gate fails on these).
    pub missing: Vec<String>,
    /// Scenarios the baseline does not know yet (report-only).
    pub added: Vec<String>,
}

/// Compare `current` against `baseline`, metric by metric.
pub fn diff_reports(baseline: &BenchReport, current: &BenchReport) -> BenchDiff {
    let mut diff = BenchDiff::default();
    for (name, bm) in &baseline.scenarios {
        match current.scenarios.get(name) {
            None => diff.missing.push(name.clone()),
            Some(cm) => {
                for (metric, &bv) in bm {
                    if let Some(&cv) = cm.get(metric) {
                        let change =
                            if bv.abs() < f64::EPSILON { 0.0 } else { (cv - bv) / bv };
                        diff.rows.push(DiffRow {
                            scenario: name.clone(),
                            metric: metric.clone(),
                            baseline: bv,
                            current: cv,
                            change,
                            gated: metric.ends_with("per_s"),
                        });
                    }
                }
            }
        }
    }
    for name in current.scenarios.keys() {
        if !baseline.scenarios.contains_key(name) {
            diff.added.push(name.clone());
        }
    }
    diff
}

/// Markdown table printer for paper-style result tables.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self, title: &str) {
        println!("\n### {title}\n");
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s
        };
        println!("{}", fmt_row(&self.headers));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        println!("{sep}");
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
        println!();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_records_samples() {
        let mut b = Bench {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(10),
            min_iters: 3,
            max_iters: 100,
            results: vec![],
        };
        let mut x = 0u64;
        let r = b.run("noop", || {
            x = x.wrapping_add(1);
            std::hint::black_box(x);
        });
        assert!(r.iters >= 3);
        assert!(r.summary.mean >= 0.0);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_dur(2.5e-9), "2.5ns");
        assert_eq!(fmt_dur(2.5e-6), "2.50µs");
        assert_eq!(fmt_dur(2.5e-3), "2.50ms");
        assert_eq!(fmt_dur(2.5), "2.500s");
    }

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    #[should_panic]
    fn table_arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".to_string()]);
    }

    #[test]
    fn bench_report_roundtrips_and_merges() {
        let path = std::env::temp_dir().join("pawd_test_bench_report.json");
        let _ = std::fs::remove_file(&path);
        let mut a = BenchReport::new();
        a.add("s1/alpha", &[("req_per_s", 120.5), ("p50_us", 40.0)]);
        a.save(&path).unwrap();
        let mut b = BenchReport::new();
        b.add("s1/beta", &[("req_per_s", 77.0)]);
        // Merge the way the bench binaries do (flush_env is this plus an
        // env lookup; mutating the environment from a parallel test binary
        // is UB on glibc, so the seam is tested directly).
        b.merge_into(&path).unwrap();
        let merged = BenchReport::load(&path).unwrap();
        assert!(!merged.provisional);
        assert_eq!(merged.scenarios.len(), 2);
        assert_eq!(merged.scenarios["s1/alpha"]["req_per_s"], 120.5);
        assert_eq!(merged.scenarios["s1/beta"]["req_per_s"], 77.0);
    }

    #[test]
    fn diff_gates_throughput_only_and_flags_missing() {
        let mut base = BenchReport::new();
        base.add("a", &[("req_per_s", 100.0), ("p99_us", 50.0)]);
        base.add("gone", &[("req_per_s", 10.0)]);
        let mut cur = BenchReport::new();
        cur.add("a", &[("req_per_s", 70.0), ("p99_us", 500.0)]);
        cur.add("fresh", &[("req_per_s", 5.0)]);
        let diff = diff_reports(&base, &cur);
        assert_eq!(diff.missing, vec!["gone".to_string()]);
        assert_eq!(diff.added, vec!["fresh".to_string()]);
        let tput = diff.rows.iter().find(|r| r.metric == "req_per_s").unwrap();
        assert!(tput.gated && tput.regressed(0.2), "-30% throughput must gate");
        assert!(!tput.regressed(0.5), "within a 50% budget it passes");
        let lat = diff.rows.iter().find(|r| r.metric == "p99_us").unwrap();
        assert!(!lat.gated && !lat.regressed(0.2), "latency is report-only");
    }
}
