//! CRC-32 (IEEE 802.3, reflected, init/xorout `0xFFFF_FFFF`) — the checksum
//! used by zlib/gzip and the `crc32fast` crate, re-implemented because the
//! build environment is offline. Table-driven, one byte per step; artifact
//! files are read once at cold start, so this is nowhere near a hot path.

const POLY: u32 = 0xEDB8_8320;

const TABLE: [u32; 256] = build_table();

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut n = 0;
    while n < 256 {
        let mut c = n as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { POLY ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[n] = c;
        n += 1;
    }
    table
}

/// CRC-32 of `bytes` (drop-in for `crc32fast::hash`).
pub fn hash(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Reference values from the CRC-32/ISO-HDLC check suite.
        assert_eq!(hash(b""), 0);
        assert_eq!(hash(b"123456789"), 0xCBF4_3926);
        assert_eq!(hash(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn sensitive_to_single_bit_flips() {
        let mut data = vec![0u8; 512];
        data[37] = 0x55;
        let a = hash(&data);
        data[400] ^= 0x01;
        assert_ne!(a, hash(&data));
    }
}
