//! Shared utilities: deterministic RNG, float codecs, JSON, parallelism,
//! stats/benchmarking, and the property-test harness.
//!
//! These exist because the build environment is offline (see DESIGN.md):
//! `rand`, `half`, `serde_json`, `rayon`, `criterion`, `proptest` and
//! `crc32fast` are re-implemented here at the scale this project needs.

pub mod benchkit;
pub mod crc32;
pub mod f16;
pub mod json;
pub mod par;
pub mod prop;
pub mod rng;
pub mod stats;
