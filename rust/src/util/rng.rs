//! Deterministic, seedable PRNGs used everywhere randomness is needed.
//!
//! The whole reproduction is seed-deterministic: model init, synthetic
//! world/corpus generation, calibration shuffles and property tests all draw
//! from these generators, so every experiment in EXPERIMENTS.md can be
//! regenerated bit-for-bit.
//!
//! `SplitMix64` is used for seeding / hashing; `Xoshiro256** ` is the
//! workhorse generator (same algorithms as the `rand` crate's
//! implementations, re-implemented here because the environment is offline).

/// SplitMix64: tiny, fast, passes BigCrush; ideal for seed expansion.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256**: general-purpose 64-bit generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal sample from Box–Muller.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 expansion (the canonical recommendation).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s, gauss_spare: None }
    }

    /// Derive an independent stream for a labelled sub-task.
    pub fn fork(&mut self, label: &str) -> Rng {
        let mut h = 0xcbf29ce484222325u64; // FNV-1a
        for b in label.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        Rng::new(self.next_u64() ^ h)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> [0,1) double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n). Unbiased via rejection (Lemire-style).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        let n = n as u64;
        // Simple rejection on the top bits; n is tiny in all our uses.
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with mean/std as f32.
    #[inline]
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fill a slice with N(0, std).
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for x in out.iter_mut() {
            *x = self.normal_f32(0.0, std);
        }
    }

    /// Pick a uniformly random element.
    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s += z;
            s2 += z * z;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork("a");
        let mut b = root.fork("b");
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(13);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 20);
    }
}
