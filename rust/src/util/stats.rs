//! Timing and summary-statistics helpers shared by benches, the serving
//! metrics module, and EXPERIMENTS.md table generation.

use std::time::{Duration, Instant};

/// Simple wall-clock timer.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Self {
        Timer(Instant::now())
    }

    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }
}

/// Summary of a sample of f64 observations.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary::default();
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        // A NaN that sneaks into a metrics sample (e.g. a 0/0 rate) must not
        // panic the percentile sort — and must not displace the finite order
        // statistics either (total_cmp alone would sort sign-bit NaNs, the
        // kind x86 0/0 actually produces, to the FRONT, shifting min/p50).
        // Order statistics are computed over the non-NaN samples; mean/std
        // keep the full sample and go NaN-poisoned, which is the visible
        // "something upstream is broken" signal.
        let mut sorted: Vec<f64> =
            samples.iter().copied().filter(|x| !x.is_nan()).collect();
        sorted.sort_by(f64::total_cmp);
        if sorted.is_empty() {
            return Summary {
                n,
                mean,
                std: var.sqrt(),
                min: f64::NAN,
                max: f64::NAN,
                p50: f64::NAN,
                p90: f64::NAN,
                p99: f64::NAN,
            };
        }
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[sorted.len() - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p99: percentile_sorted(&sorted, 0.99),
        }
    }
}

/// Percentile (nearest-rank with linear interpolation) of a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Mean of f32 slice as f64.
pub fn mean_f32(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64
    }
}

/// Fixed-bucket latency histogram (power-of-two microsecond buckets).
/// Lock-free recording is handled by the caller (metrics module wraps it in
/// per-thread instances merged at read time).
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    /// bucket i counts samples in [2^i, 2^(i+1)) microseconds; bucket 0 is [0,2).
    buckets: Vec<u64>,
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram { buckets: vec![0; 40], count: 0, sum_us: 0, max_us: 0 }
    }

    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = (64 - us.max(1).leading_zeros() as usize).min(self.buckets.len() - 1);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Approximate quantile from the bucket histogram (upper bucket edge).
    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                return 1u64 << i;
            }
        }
        self.max_us
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile_sorted(&sorted, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 1.0), 10.0);
    }

    #[test]
    fn histogram_quantiles_monotone() {
        let mut h = LatencyHistogram::new();
        for us in [1u64, 10, 100, 1000, 10_000, 100_000] {
            for _ in 0..10 {
                h.record(Duration::from_micros(us));
            }
        }
        assert_eq!(h.count(), 60);
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.9));
        assert!(h.quantile_us(0.9) <= h.quantile_us(0.999).max(h.max_us()));
        assert!(h.mean_us() > 0.0);
    }

    #[test]
    fn histogram_merge_adds() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record(Duration::from_micros(5));
        b.record(Duration::from_micros(500));
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.max_us(), 500);
    }

    #[test]
    fn empty_summary_is_safe() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
    }

    #[test]
    fn nan_samples_do_not_panic_the_percentile_sort() {
        // NaN latencies (e.g. a 0/0 rate upstream) must degrade gracefully:
        // order statistics come from the finite samples regardless of the
        // NaN's sign bit (x86 0/0 produces a *negative* NaN, which
        // total_cmp alone would sort to the front), while mean goes
        // NaN-poisoned as the upstream-breakage signal.
        let s = Summary::of(&[3.0, f64::NAN, 1.0, -f64::NAN, 2.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.min, 1.0, "negative NaN must not displace the finite minimum");
        assert_eq!(s.max, 3.0, "positive NaN must not displace the finite maximum");
        assert_eq!(s.p50, 2.0, "median of the finite samples [1, 2, 3]");
        assert!(s.mean.is_nan(), "mean keeps the poison as the visible signal");
        // All-NaN input is also panic-free.
        let all_nan = Summary::of(&[f64::NAN, f64::NAN]);
        assert_eq!(all_nan.n, 2);
        assert!(all_nan.p50.is_nan() && all_nan.min.is_nan());
    }
}
