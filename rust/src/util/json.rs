//! Minimal JSON parser/writer.
//!
//! `serde`/`serde_json` are not available offline; the AOT manifest
//! (`artifacts/manifest.json`, written by Python's `json` module) and a few
//! config files need standard JSON. This implements the full JSON grammar
//! (objects, arrays, strings with escapes incl. \uXXXX, numbers, booleans,
//! null) with byte-offset error reporting.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(|n| if n.fract() == 0.0 { Some(n as i64) } else { None })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Required-field helpers that produce useful errors.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing key '{key}'"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?.as_str().ok_or_else(|| anyhow::anyhow!("key '{key}' is not a string"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("key '{key}' is not a non-negative integer"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.req(key)?.as_arr().ok_or_else(|| anyhow::anyhow!("key '{key}' is not an array"))
    }
}

// -- writer ---------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

// -- parser ---------------------------------------------------------------

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // Handle surrogate pairs.
                            if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.i += 1;
                                    self.eat(b'u')?;
                                    let lo = self.hex4()?;
                                    let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    s.push(
                                        char::from_u32(c)
                                            .ok_or_else(|| self.err("bad surrogate pair"))?,
                                    );
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else {
                                s.push(
                                    char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?,
                                );
                            }
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // Re-assemble UTF-8 multibyte sequences byte-by-byte.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.b.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..start + len])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hs = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(hs, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().map_or(false, |c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while self.peek().map_or(false, |c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().map_or(false, |c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

/// Convenience constructors for building manifests/configs in Rust.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn n(v: f64) -> Json {
    Json::Num(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x\ny");
        let a = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parse_unicode_escapes() {
        let j = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "é😀");
    }

    #[test]
    fn parse_utf8_passthrough() {
        let j = Json::parse("\"héllo — 世界\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo — 世界");
    }

    #[test]
    fn errors_have_offsets() {
        let e = Json::parse("{\"a\": }").unwrap_err();
        assert!(e.offset >= 6);
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} garbage").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn roundtrip_display_parse() {
        let src = r#"{"model":"llama-mini","dims":[256,4],"lr":0.0001,"ok":true,"x":null,"s":"a\"b\\c\nd"}"#;
        let j = Json::parse(src).unwrap();
        let printed = j.to_string();
        let j2 = Json::parse(&printed).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn typed_accessors() {
        let j = Json::parse(r#"{"n": 7, "f": 1.5}"#).unwrap();
        assert_eq!(j.req_usize("n").unwrap(), 7);
        assert!(j.req_usize("f").is_err());
        assert!(j.req_str("missing").is_err());
        assert_eq!(j.get("f").unwrap().as_f64().unwrap(), 1.5);
    }

    #[test]
    fn builders_produce_valid_json() {
        let j = obj(vec![("name", s("x")), ("vals", arr(vec![n(1.0), n(2.0)]))]);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.req_str("name").unwrap(), "x");
    }
}
