//! Seeded property-testing harness.
//!
//! `proptest` is unavailable offline; this provides the same invariant-sweep
//! style: each property runs `cases` times with a deterministic per-case RNG
//! and a growing size parameter. On failure the harness retries the failing
//! case at smaller sizes (a cheap shrink) and reports the seed so the exact
//! case can be replayed with `PAWD_PROP_SEED`.

use super::rng::Rng;

/// Per-case generation context.
pub struct Gen {
    pub rng: Rng,
    /// Size hint in [1, max_size]; grows over the case index so early cases
    /// exercise tiny shapes and later cases larger ones.
    pub size: usize,
}

impl Gen {
    /// Dimension in [1, size].
    pub fn dim(&mut self) -> usize {
        self.rng.range(1, self.size + 1)
    }

    /// Dimension in [lo, lo+size].
    pub fn dim_at_least(&mut self, lo: usize) -> usize {
        lo + self.rng.below(self.size + 1)
    }

    /// Vector of normal f32s of length n.
    pub fn vec_normal(&mut self, n: usize, std: f32) -> Vec<f32> {
        let mut v = vec![0f32; n];
        self.rng.fill_normal(&mut v, std);
        v
    }

    /// Vector with occasional special values (zeros, tiny, large, negatives).
    pub fn vec_nasty(&mut self, n: usize) -> Vec<f32> {
        (0..n)
            .map(|_| match self.rng.below(8) {
                0 => 0.0,
                1 => -0.0,
                2 => 1e-30,
                3 => -1e-30,
                4 => 1e20,
                5 => -1e20,
                _ => self.rng.normal_f32(0.0, 1.0),
            })
            .collect()
    }
}

/// Run a property. `f` returns Err(description) on violation.
///
/// Panics with a replayable report on failure.
pub fn check<F>(name: &str, cases: usize, max_size: usize, f: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    let base_seed = std::env::var("PAWD_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0x9E3779B97F4A7C15);
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0xA24BAED4963EE407);
        // Size ramps from 1 to max_size across cases.
        let size = 1 + (case * max_size) / cases.max(1);
        let mut g = Gen { rng: Rng::new(seed), size: size.max(1) };
        if let Err(msg) = f(&mut g) {
            // Shrink attempt: replay the same seed at smaller sizes and
            // report the smallest size that still fails.
            let mut min_fail = size;
            for s in 1..size {
                let mut g2 = Gen { rng: Rng::new(seed), size: s };
                if f(&mut g2).is_err() {
                    min_fail = s;
                    break;
                }
            }
            panic!(
                "property '{name}' failed (case {case}, size {size}, min-fail size {min_fail}, \
                 seed {seed}): {msg}\nreplay with PAWD_PROP_SEED={base_seed}"
            );
        }
    }
}

/// Assert two f32 slices are elementwise close.
pub fn assert_close(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if !(x - y).abs().le(&tol) {
            return Err(format!("index {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("trivial", 50, 16, |g| {
            let n = g.dim();
            if n >= 1 {
                Ok(())
            } else {
                Err("dim < 1".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 5, 8, |_| Err("nope".into()));
    }

    #[test]
    fn assert_close_catches_mismatch() {
        assert!(assert_close(&[1.0], &[1.0 + 1e-7], 1e-6, 0.0).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-6, 1e-6).is_err());
        assert!(assert_close(&[1.0, 2.0], &[1.0], 1e-6, 0.0).is_err());
    }

    #[test]
    fn nasty_vectors_have_extremes() {
        let mut g = Gen { rng: Rng::new(1), size: 10 };
        let v = g.vec_nasty(1000);
        assert!(v.iter().any(|&x| x == 0.0));
        assert!(v.iter().any(|&x| x.abs() >= 1e19));
    }
}
