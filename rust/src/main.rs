//! `pawd` CLI — leader entrypoint for the serving stack and the offline
//! compression pipeline. Hand-rolled argument parsing (clap is unavailable
//! offline).

use anyhow::{bail, Context, Result};
use pawd::coordinator::{Engine, Server, ServerConfig, VariantStore};
use pawd::delta::format::load_delta;
use pawd::model::checkpoint::load_fp16;
use pawd::model::ModelConfig;
use pawd::pipeline::PairConfig;
use pawd::util::benchkit::fmt_bytes;
use std::path::PathBuf;
use std::sync::Arc;

const USAGE: &str = "\
pawd — Per-Axis Weight Deltas for Frequent Model Updates

USAGE:
  pawd pipeline <config> <out_dir> [--full]      train pair + compress + eval (needs artifacts)
  pawd inspect <file.pawd>                       describe a delta artifact
  pawd apply <base.fp16> <delta.pawd> <out.fp16> materialize a variant checkpoint
  pawd serve <base.fp16> <variant_dir> [--http <addr>]
                                                 start the serving coordinator; without
                                                 --http, run a demo probe loop and exit;
                                                 with --http (e.g. 127.0.0.1:7421), serve
                                                 the network plane until interrupted:
                                                 POST /v1/query, POST /v1/admin/<op>,
                                                 GET /v1/sync/manifest (long-poll),
                                                 GET /v1/sync/file/<name>
  pawd bench-load <base.fp16> <variant_dir> <n>  time cold loads of every variant n times
  pawd publish <variant_dir> <name> <delta.pawd> [--parent [N]]
               [--fit <base.fp16> <ft.fp16>] [--codec <c>] [--lowrank-rank N]
                                                 publish the next version of a variant;
                                                 with --parent, ship an incremental patch
                                                 carrying only the modules changed vs N
                                                 (default: the active version); with
                                                 --fit, first compress the checkpoint
                                                 pair into <delta.pawd> using --codec
                                                 (per-axis | scalar | lowrank | auto;
                                                 auto = per-module shoot-out on
                                                 calibration error, default per-axis);
                                                 --lowrank-rank sets the lowrank codec's
                                                 rank (default 4)
  pawd consolidate <variant_dir> <name> [version]
                                                 rebase a version's patch chain into a
                                                 single full artifact in place
  pawd rollback <variant_dir> <name> [version]   flip a variant's alias back
  pawd versions <variant_dir>                    list variants + version histories
  pawd gc <variant_dir> [name]                   delete retired versions' artifact files
  pawd replicate <variant_dir> --from <leader> [--follow] [--interval-ms N]
                                                 pull-replicate a leader registry into
                                                 <variant_dir>: fetch only missing
                                                 artifacts (patches when the chain parent
                                                 is already held), verify crcs, commit.
                                                 <leader> is a directory, or an
                                                 http://host:port of a `serve --http`
                                                 frontend; --follow keeps tracking the
                                                 leader's manifest_seq until interrupted
                                                 (fs: poll every N ms, default 500;
                                                 http: long-poll, header bytes when idle)
  pawd bench-diff <baseline.json> <current.json> [--max-regression 0.20] [--promote]
                                                 diff two BENCH_*.json files (CI perf
                                                 gate); --promote overwrites the baseline
                                                 with the current report from a trusted run
  pawd audit [--json] [--root <dir>]             run the repo static analysis passes
                                                 (bracket balance, use resolution,
                                                 exhaustive matches, registry drift,
                                                 unsafe inventory, condvar loops); exits
                                                 non-zero on any finding. See the README
                                                 \"Static analysis & sanitizers\" section
  pawd presets                                   list model config presets

publish/consolidate/rollback/versions/gc administer a variant directory
OFFLINE — one process owns a registry dir at a time, so never point them at
a directory a running `pawd serve` owns (use the server's admin client
instead).

Artifacts are built with `make artifacts`; examples/ and benches/ cover the
paper's experiments (see DESIGN.md / EXPERIMENTS.md).";

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("pipeline") => cmd_pipeline(&args[1..]),
        Some("inspect") => cmd_inspect(&args[1..]),
        Some("apply") => cmd_apply(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("bench-load") => cmd_bench_load(&args[1..]),
        Some("publish") => cmd_publish(&args[1..]),
        Some("consolidate") => cmd_consolidate(&args[1..]),
        Some("rollback") => cmd_rollback(&args[1..]),
        Some("versions") => cmd_versions(&args[1..]),
        Some("gc") => cmd_gc(&args[1..]),
        Some("replicate") => cmd_replicate(&args[1..]),
        Some("bench-diff") => cmd_bench_diff(&args[1..]),
        Some("audit") => {
            let findings = pawd::audit::cli_audit(&args[1..])?;
            if findings > 0 {
                std::process::exit(1);
            }
            Ok(())
        }
        Some("presets") => {
            for p in ["tiny", "llama-mini", "qwen-mini", "phi-mini", "base-110m"] {
                let c = ModelConfig::preset(p).unwrap();
                println!(
                    "{:<12} dim {:>4}  layers {:>2}  heads {:>2}  ff {:>4}  params {:>7.2}M",
                    c.name,
                    c.dim,
                    c.n_layers,
                    c.n_heads,
                    c.ff,
                    c.n_params() as f64 / 1e6
                );
            }
            Ok(())
        }
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn cmd_pipeline(args: &[String]) -> Result<()> {
    let config = args.first().context("missing <config>")?;
    let out_dir = PathBuf::from(args.get(1).context("missing <out_dir>")?);
    let full = args.iter().any(|a| a == "--full");
    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let h = pawd::runtime::start(&artifacts)?;
    let pc = if full { PairConfig::full(config) } else { PairConfig::quick(config) };
    let methods = vec![
        ("BitDelta (scalar)", pawd::baselines::bitdelta_options(), false),
        ("Vector (row/col)", pawd::baselines::vector_options(), true),
    ];
    let res = pawd::pipeline::run_pair(&h, &pc, &methods, &out_dir, |m| println!("{m}"))?;
    println!("\nbaseline avg {:.2}%", res.baseline_suite.average() * 100.0);
    for m in &res.methods {
        println!(
            "{:<20} avg {:.2}%  artifact {} ({:.2}x smaller than fp16)",
            m.method,
            m.suite.average() * 100.0,
            fmt_bytes(m.artifact_bytes),
            res.fp16_bytes as f64 / m.artifact_bytes as f64
        );
    }
    h.shutdown();
    Ok(())
}

fn cmd_inspect(args: &[String]) -> Result<()> {
    let path = args.first().context("missing <file.pawd>")?;
    let model = load_delta(path)?;
    println!("variant      : {}", model.variant);
    println!("base config  : {}", model.base_config);
    println!("modules      : {}", model.modules.len());
    println!("payload      : {}", fmt_bytes(model.payload_bytes()));
    let codec_counts: Vec<String> = pawd::delta::CodecKind::ALL
        .iter()
        .filter_map(|k| {
            let n = model.modules.iter().filter(|m| m.codec.kind() == *k).count();
            (n > 0).then(|| format!("{} {n}", k.label()))
        })
        .collect();
    println!("codecs       : {}", codec_counts.join("  "));
    for (kind, row, col) in model.axis_counts_by_kind() {
        println!("  {:<10} row {:>3}  col {:>3}", kind.name(), row, col);
    }
    Ok(())
}

fn cmd_apply(args: &[String]) -> Result<()> {
    let base = load_fp16(args.first().context("missing <base.fp16>")?)?;
    let delta = load_delta(args.get(1).context("missing <delta.pawd>")?)?;
    if delta.base_config != base.cfg().name {
        bail!("delta targets '{}', base is '{}'", delta.base_config, base.cfg().name);
    }
    let variant = pawd::delta::apply::materialize(&base, &delta.modules);
    let out = args.get(2).context("missing <out.fp16>")?;
    let bytes = pawd::model::checkpoint::save_fp16(out, &variant)?;
    println!("wrote {} ({})", out, fmt_bytes(bytes));
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let mut positional: Vec<&String> = Vec::new();
    let mut http: Option<String> = None;
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--http" {
            let addr = args.get(i + 1).context("--http needs an address (e.g. 127.0.0.1:7421)")?;
            http = Some(addr.clone());
            i += 2;
        } else {
            positional.push(&args[i]);
            i += 1;
        }
    }
    let base = Arc::new(load_fp16(positional.first().copied().context("missing <base.fp16>")?)?);
    let dir = PathBuf::from(positional.get(1).copied().context("missing <variant_dir>")?);
    let store = VariantStore::open(base, &dir)?;
    let names = store.list()?;
    println!("serving {} variants from {}: {:?}", names.len(), dir.display(), names);
    let server = Server::start(store, Engine::Native, ServerConfig::default());
    let client = server.client();
    if let Some(addr) = http {
        let registry = server.cache.store().registry().clone();
        let frontend = pawd::net::HttpFrontend::start(
            &addr,
            Some(server.client()),
            registry,
            pawd::net::FrontConfig::default(),
        )
        .with_context(|| format!("binding http frontend on {addr}"))?;
        println!(
            "http plane on {} — POST /v1/query, POST /v1/admin/<op>, \
             GET /v1/sync/manifest (long-poll), GET /v1/sync/file/<name>",
            frontend.url()
        );
        // Serve until killed; a periodic summary keeps the console honest.
        loop {
            std::thread::sleep(std::time::Duration::from_secs(30));
            let snap = server.metrics.snapshot();
            println!(
                "served {} requests ({} http requests, {} manifest long-polls), \
                 {} cold starts, {} engine steps, prefix cache {}/{} hit/miss \
                 ({} resident, {} rows skipped)",
                snap.served,
                snap.http_requests,
                snap.http_long_polls,
                snap.cold_starts,
                snap.engine_steps,
                snap.prefix_cache_hits,
                snap.prefix_cache_misses,
                fmt_bytes(snap.prefix_cache_bytes),
                snap.prefix_rows_skipped
            );
            println!(
                "  exec: {} base gemms, {} pool tasks ({} ns idle), \
                 {} activation rows; loader {} in {} reads \
                 ({} modules inherited); wire {} in {} files",
                snap.base_gemms,
                snap.pool_tasks,
                snap.pool_steal_or_idle_ns,
                snap.activation_row_reads,
                fmt_bytes(snap.loader_bytes),
                snap.module_reads,
                snap.modules_inherited,
                fmt_bytes(snap.wire_bytes),
                snap.wire_files
            );
        }
    }
    // Demo loop: probe each variant once, print metrics, exit. (`--http`
    // is the network front-end over this same `Server::client()`.)
    for name in &names {
        let resp = client.score(name, "Q: health probe? A: ", &["ok".into(), "bad".into()]);
        println!("  {name}: ok={:?} in {:?}", resp.result.is_ok(), resp.timing.total);
    }
    let snap = server.metrics.snapshot();
    println!(
        "served {} requests ({} http requests, {} manifest long-polls), {} cold starts, \
         {} engine steps, {} pool tasks, prefix cache {}/{} hit/miss ({} resident, \
         {} rows skipped)",
        snap.served,
        snap.http_requests,
        snap.http_long_polls,
        snap.cold_starts,
        snap.engine_steps,
        snap.pool_tasks,
        snap.prefix_cache_hits,
        snap.prefix_cache_misses,
        fmt_bytes(snap.prefix_cache_bytes),
        snap.prefix_rows_skipped
    );
    server.shutdown();
    Ok(())
}

fn cmd_publish(args: &[String]) -> Result<()> {
    // Positional args first, then the optional flags.
    let mut positional: Vec<&String> = Vec::new();
    let mut incremental = false;
    let mut parent: Option<u32> = None;
    let mut fit: Option<(String, String)> = None;
    let mut codec = pawd::delta::CodecChoice::PerAxis;
    let mut lowrank_rank: Option<usize> = None;
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--parent" {
            incremental = true;
            if let Some(v) = args.get(i + 1).and_then(|s| s.parse::<u32>().ok()) {
                parent = Some(v);
                i += 2;
            } else {
                i += 1;
            }
        } else if args[i] == "--fit" {
            let b = args.get(i + 1).context("--fit needs <base.fp16> <ft.fp16>")?.clone();
            let f = args.get(i + 2).context("--fit needs <base.fp16> <ft.fp16>")?.clone();
            fit = Some((b, f));
            i += 3;
        } else if args[i] == "--codec" {
            let c = args.get(i + 1).context("--codec needs a value")?;
            codec = pawd::delta::CodecChoice::parse(c)
                .with_context(|| format!("unknown codec '{c}' (per-axis|scalar|lowrank|auto)"))?;
            i += 2;
        } else if args[i] == "--lowrank-rank" {
            let r = args
                .get(i + 1)
                .context("--lowrank-rank needs a value (e.g. 4)")?
                .parse::<usize>()
                .context("bad --lowrank-rank value")?;
            if r == 0 {
                bail!("--lowrank-rank must be >= 1");
            }
            lowrank_rank = Some(r);
            i += 2;
        } else {
            positional.push(&args[i]);
            i += 1;
        }
    }
    let dir = PathBuf::from(positional.first().copied().context("missing <variant_dir>")?);
    let name = positional.get(1).copied().context("missing <name>")?;
    let artifact = PathBuf::from(positional.get(2).copied().context("missing <delta.pawd>")?);
    if let Some((base_p, ft_p)) = fit {
        let base = load_fp16(&base_p)?;
        let ft = load_fp16(&ft_p)?;
        // Deterministic synthetic calibration docs (same recipe as the
        // benches) so repeated fits of the same pair are bit-identical.
        let docs: Vec<Vec<u8>> = (0..6)
            .map(|i| (0..48).map(|t| ((t * 7 + i * 13) % 250 + 1) as u8).collect())
            .collect();
        let mut opts = pawd::delta::CompressOptions {
            fit: pawd::delta::FitMode::ClosedForm,
            codec,
            ..Default::default()
        };
        if let Some(r) = lowrank_rank {
            opts.lowrank_rank = r;
        }
        let (model, _reports, _) = pawd::delta::compress_model(name, &base, &ft, &docs, &opts);
        let bytes = pawd::delta::format::save_delta(&artifact, &model)?;
        let counts: Vec<String> = pawd::delta::CodecKind::ALL
            .iter()
            .map(|k| {
                let n = model.modules.iter().filter(|m| m.codec.kind() == *k).count();
                format!("{} {n}", k.label())
            })
            .collect();
        println!(
            "fitted {} with --codec {} [{}] -> {} ({})",
            name,
            codec.label(),
            counts.join(", "),
            artifact.display(),
            fmt_bytes(bytes)
        );
    }
    let registry = pawd::coordinator::VariantRegistry::open(&dir)?;
    if incremental {
        let model = load_delta(&artifact)?;
        if model.meta.is_patch {
            bail!("{} is already a patch artifact; pass the effective model", artifact.display());
        }
        let out = registry.publish_incremental(name, model, parent)?;
        println!(
            "published {name}@{} into {} as {} ({})",
            out.version,
            dir.display(),
            if out.patch { "an incremental patch" } else { "a full artifact (no usable diff)" },
            fmt_bytes(out.bytes)
        );
    } else {
        let version = registry.publish_file(name, &artifact)?;
        println!("published {name}@{version} into {}", dir.display());
    }
    Ok(())
}

fn cmd_consolidate(args: &[String]) -> Result<()> {
    let dir = PathBuf::from(args.first().context("missing <variant_dir>")?);
    let name = args.get(1).context("missing <name>")?;
    let version: Option<u32> = args.get(2).map(|s| s.parse()).transpose()?;
    let registry = pawd::coordinator::VariantRegistry::open(&dir)?;
    let out = registry.consolidate(name, version)?;
    if out.rebased_links == 0 {
        println!("{name}@{} is already a full artifact ({})", out.version, fmt_bytes(out.bytes));
    } else {
        println!(
            "consolidated {name}@{}: {} chain links rebased into one full artifact ({})",
            out.version,
            out.rebased_links,
            fmt_bytes(out.bytes)
        );
    }
    Ok(())
}

fn cmd_rollback(args: &[String]) -> Result<()> {
    let dir = PathBuf::from(args.first().context("missing <variant_dir>")?);
    let name = args.get(1).context("missing <name>")?;
    let to: Option<u32> = args.get(2).map(|s| s.parse()).transpose()?;
    let registry = pawd::coordinator::VariantRegistry::open(&dir)?;
    let version = registry.rollback(name, to)?;
    println!("{name} now serves version {version}");
    Ok(())
}

fn cmd_versions(args: &[String]) -> Result<()> {
    let dir = PathBuf::from(args.first().context("missing <variant_dir>")?);
    let registry = pawd::coordinator::VariantRegistry::open(&dir)?;
    for d in registry.list() {
        let pin = if d.pinned { " (pinned)" } else { "" };
        println!("{}: active v{}{}", d.name, d.active, pin);
        for v in &d.versions {
            println!(
                "  v{:<3} {:<22} {:>10}  parent {}  {}{}{}",
                v.version,
                v.file,
                fmt_bytes(v.bytes),
                v.parent.map_or("-".to_string(), |p| format!("v{p}")),
                if v.created_unix > 0 { format!("t={}", v.created_unix) } else { "adopted".into() },
                if v.patch { "  [patch]" } else { "" },
                if v.retired { "  [retired]" } else { "" },
            );
        }
    }
    Ok(())
}

fn cmd_gc(args: &[String]) -> Result<()> {
    let dir = PathBuf::from(args.first().context("missing <variant_dir>")?);
    let name = args.get(1).map(|s| s.as_str());
    let registry = pawd::coordinator::VariantRegistry::open(&dir)?;
    let report = registry.gc(name)?;
    println!(
        "gc: removed {} retired artifact file(s), freed {}",
        report.files_removed,
        fmt_bytes(report.bytes_freed)
    );
    Ok(())
}

fn cmd_replicate(args: &[String]) -> Result<()> {
    use pawd::coordinator::{FsTransport, Replicator, SyncTransport, VariantRegistry};
    let mut positional: Vec<&String> = Vec::new();
    let mut from: Option<String> = None;
    let mut follow = false;
    let mut interval_ms: u64 = 500;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--from" => {
                from = Some(
                    args.get(i + 1)
                        .context("--from needs a leader directory or http://host:port")?
                        .clone(),
                );
                i += 2;
            }
            "--follow" => {
                follow = true;
                i += 1;
            }
            "--interval-ms" => {
                interval_ms = args
                    .get(i + 1)
                    .context("--interval-ms needs a value")?
                    .parse()
                    .context("bad --interval-ms value")?;
                i += 2;
            }
            _ => {
                positional.push(&args[i]);
                i += 1;
            }
        }
    }
    let dir = PathBuf::from(positional.first().copied().context("missing <variant_dir>")?);
    let from = from.context("missing --from <leader_dir | http://host:port>")?;
    let over_http = from.starts_with("http://");
    let transport: Box<dyn SyncTransport> = if over_http {
        Box::new(pawd::net::HttpTransport::new(&from)?)
    } else {
        let from_dir = PathBuf::from(&from);
        if from_dir == dir {
            bail!("leader and follower directories must differ");
        }
        Box::new(FsTransport::new(&from_dir))
    };
    let registry = Arc::new(VariantRegistry::open(&dir)?);
    let replicator = Replicator::new(registry.clone(), transport);
    // One long-poll window per follow pass over HTTP; idle passes cost
    // header bytes only, and a publish on the leader wakes the poll early.
    let poll_window = std::time::Duration::from_millis(interval_ms.max(10).max(5_000));
    // This CLI administers an *offline* follower directory (same rule as
    // publish/gc): no server, so there is no cache to warm.
    loop {
        // In follow mode a transient failure (leader gc racing a fetch, a
        // shared-fs blip) must not kill the daemon — report and retry at
        // the next tick; completed variants stay committed either way.
        let pass = if follow && over_http {
            replicator.sync_wait(None, poll_window)
        } else {
            replicator.sync_once(None)
        };
        let report = match pass {
            Ok(r) => r,
            Err(e) if follow => {
                eprintln!("sync from {} failed (will retry): {e:#}", replicator.peer());
                std::thread::sleep(std::time::Duration::from_millis(interval_ms.max(10)));
                continue;
            }
            Err(e) => return Err(e),
        };
        if report.up_to_date {
            if !follow {
                println!(
                    "up to date with {} (leader manifest_seq {})",
                    replicator.peer(),
                    report.leader_seq
                );
            }
        } else {
            println!(
                "synced {} variant(s) from {}: {} version(s) installed, {} file(s) / {} \
                 fetched ({} patch artifact(s)); local manifest_seq {}",
                report.variants_synced,
                replicator.peer(),
                report.versions_installed,
                report.files_fetched,
                fmt_bytes(report.artifact_bytes),
                report.patch_files_fetched,
                registry.manifest_seq(),
            );
        }
        if !follow {
            return Ok(());
        }
        if !over_http {
            // Filesystem leaders have no change notification; poll.
            std::thread::sleep(std::time::Duration::from_millis(interval_ms.max(10)));
        }
    }
}

fn cmd_bench_diff(args: &[String]) -> Result<()> {
    use pawd::util::benchkit::{diff_reports, BenchReport, Table};
    let mut paths: Vec<&String> = Vec::new();
    let mut max_regression = 0.20f64;
    let mut promote = false;
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--max-regression" {
            max_regression = args
                .get(i + 1)
                .context("--max-regression needs a value (e.g. 0.20)")?
                .parse()?;
            i += 2;
        } else if args[i] == "--promote" {
            promote = true;
            i += 1;
        } else {
            paths.push(&args[i]);
            i += 1;
        }
    }
    if paths.len() != 2 {
        bail!(
            "usage: pawd bench-diff <baseline.json> <current.json> \
             [--max-regression 0.20] [--promote]"
        );
    }
    let (baseline_path, current_path) = (paths[0], paths[1]);
    let baseline = BenchReport::load(baseline_path)?;
    let current = BenchReport::load(current_path)?;
    if current.scenarios.is_empty() {
        bail!("{current_path}: no scenarios — the benches produced no JSON output");
    }
    let diff = diff_reports(&baseline, &current);
    let mut t = Table::new(&["scenario", "metric", "baseline", "current", "change", "gate"]);
    let mut regressions = 0usize;
    for r in &diff.rows {
        let verdict = if !r.gated {
            "-"
        } else if r.regressed(max_regression) {
            regressions += 1;
            "REGRESSED"
        } else {
            "ok"
        };
        t.row(&[
            r.scenario.clone(),
            r.metric.clone(),
            format!("{:.3}", r.baseline),
            format!("{:.3}", r.current),
            format!("{:+.1}%", r.change * 100.0),
            verdict.to_string(),
        ]);
    }
    t.print(&format!(
        "bench diff: {current_path} vs {baseline_path} (gate: throughput -{:.0}%)",
        max_regression * 100.0
    ));
    for name in &diff.added {
        println!("new scenario (no baseline yet): {name}");
    }
    for name in &diff.missing {
        println!("MISSING scenario (present in baseline): {name}");
    }
    // Promote: overwrite the baseline with the current report (provisional
    // flag dropped) so the next diff gates against this trusted run. A run
    // that fails the armed gate must not become the new baseline.
    let do_promote = || -> Result<()> {
        if !promote {
            return Ok(());
        }
        let mut promoted = current.clone();
        promoted.provisional = false;
        promoted.save(baseline_path)?;
        println!("promoted {current_path} over {baseline_path} (gate is now armed)");
        Ok(())
    };
    if baseline.provisional {
        if promote {
            return do_promote();
        }
        println!(
            "baseline is PROVISIONAL — gate is report-only. Promote a trusted run with \
             `pawd bench-diff {baseline_path} {current_path} --promote`."
        );
        return Ok(());
    }
    if regressions > 0 || !diff.missing.is_empty() {
        bail!(
            "perf gate failed: {regressions} regressed metric(s), {} missing scenario(s)",
            diff.missing.len()
        );
    }
    println!("perf gate passed");
    do_promote()
}

fn cmd_bench_load(args: &[String]) -> Result<()> {
    let base = Arc::new(load_fp16(args.first().context("missing <base.fp16>")?)?);
    let dir = PathBuf::from(args.get(1).context("missing <variant_dir>")?);
    let n: usize = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(10);
    let store = VariantStore::open(base, &dir)?;
    for name in store.list()? {
        let mut times = Vec::new();
        for _ in 0..n {
            let v = store.load(&name)?;
            times.push(v.load_time.as_secs_f64());
        }
        let s = pawd::util::stats::Summary::of(&times);
        println!(
            "{name}: mean {:.2}ms p50 {:.2}ms over {n} loads",
            s.mean * 1e3,
            s.p50 * 1e3
        );
    }
    Ok(())
}
