//! A002 `use-resolution`: every crate-internal `use` path (`crate::`,
//! `super::`, `self::` inside the lib, `pawd::` from tests/benches/
//! examples) must resolve to a declared module, item, or `pub use`
//! re-export.
//!
//! The resolver builds a module tree from `rust/src` by scanning scrubbed
//! source: `mod x;` / inline `mod x { .. }` declarations, item keywords in
//! statement position, and `pub use` re-exports (named leaves become
//! members; a glob re-export marks the module "open", and lookups that
//! land in an open module are skipped rather than flagged). Visibility is
//! deliberately ignored — the pass audits *existence*, the compiler audits
//! privacy.

use super::lexer::{
    allow_lines, at_stmt_pos, is_ident_char, line_of, match_brace, next_ident, scrub, skip_ws,
    word_positions,
};
use super::{Finding, SourceTree};
use std::collections::{BTreeMap, BTreeSet};

#[derive(Default)]
pub struct Module {
    pub items: BTreeSet<String>,
    pub submodules: BTreeSet<String>,
    pub has_glob_reexport: bool,
    pub parsed: bool,
}

pub struct UseDecl {
    pub rel: String,
    pub modpath: String,
    pub tree: String,
    pub line: usize,
}

const ITEM_KEYWORDS: &[&str] =
    &["fn", "struct", "enum", "trait", "union", "type", "const", "static"];

/// `(segments, alias)` leaves of a use tree like `a::{b, c as d, e::*}`.
pub fn split_use_tree(tree: &str) -> Vec<(Vec<String>, Option<String>)> {
    let mut results = Vec::new();
    rec(&mut results, &[], tree);
    return results;

    fn rec(results: &mut Vec<(Vec<String>, Option<String>)>, prefix: &[String], t: &str) {
        let t = t.trim();
        let brace = t.find('{');
        match brace {
            None => {
                let mut segs: Vec<String> = prefix.to_vec();
                segs.extend(t.split("::").map(|s| s.trim().to_string()).filter(|s| !s.is_empty()));
                let mut alias = None;
                if let Some(last) = segs.last().cloned() {
                    if let Some(p) = last.find(" as ") {
                        let (name, al) = last.split_at(p);
                        *segs.last_mut().unwrap() = name.trim().to_string();
                        alias = Some(al[4..].trim().to_string());
                    }
                }
                results.push((segs, alias));
            }
            Some(b) => {
                let mut head = t[..b].trim_end();
                if let Some(h) = head.strip_suffix("::") {
                    head = h;
                }
                let mut segs: Vec<String> = prefix.to_vec();
                segs.extend(
                    head.split("::").map(|s| s.trim().to_string()).filter(|s| !s.is_empty()),
                );
                let close = t.rfind('}').unwrap_or(t.len());
                let inner = &t[b + 1..close];
                let mut depth = 0i64;
                let mut part = String::new();
                for ch in inner.chars() {
                    match ch {
                        '{' => depth += 1,
                        '}' => depth -= 1,
                        _ => {}
                    }
                    if ch == ',' && depth == 0 {
                        if !part.trim().is_empty() {
                            rec(results, &segs, &part);
                        }
                        part.clear();
                    } else {
                        part.push(ch);
                    }
                }
                if !part.trim().is_empty() {
                    rec(results, &segs, &part);
                }
            }
        }
    }
}

/// Does the keyword at `kw_start` carry a `pub` / `pub(...)` prefix?
fn has_pub_prefix(text: &[char], kw_start: usize) -> bool {
    let mut i = kw_start;
    while i > 0 && text[i - 1].is_whitespace() {
        i -= 1;
    }
    if i > 0 && text[i - 1] == ')' {
        let mut d = 0i64;
        let mut j = i;
        while j > 0 {
            j -= 1;
            if text[j] == ')' {
                d += 1;
            } else if text[j] == '(' {
                d -= 1;
                if d == 0 {
                    break;
                }
            }
        }
        while j > 0 && text[j - 1].is_whitespace() {
            j -= 1;
        }
        i = j;
    }
    i >= 3
        && text[i - 3..i] == ['p', 'u', 'b']
        && (i == 3 || !is_ident_char(text[i - 4]))
}

/// Scan one (scrubbed) file, tracking inline `mod x { .. }` nesting, and
/// record items / submodules / use decls per module path.
fn parse_modules_in_file(
    rel: &str,
    scrubbed: &[char],
    base_modpath: &str,
    modules: &mut BTreeMap<String, Module>,
    uses: &mut Vec<UseDecl>,
) {
    walk(rel, scrubbed, 0, scrubbed.len(), base_modpath, modules, uses);

    #[allow(clippy::too_many_arguments)]
    fn walk(
        rel: &str,
        scrubbed: &[char],
        seg_start: usize,
        seg_end: usize,
        modpath: &str,
        modules: &mut BTreeMap<String, Module>,
        uses: &mut Vec<UseDecl>,
    ) {
        modules.entry(modpath.to_string()).or_default().parsed = true;
        let mut masked: Vec<char> = scrubbed[seg_start..seg_end].to_vec();
        // inline / declared submodules first, masking inline bodies out
        let mut pos = 0usize;
        loop {
            let next = word_positions(&masked, "mod").into_iter().find(|&p| p >= pos);
            let p = match next {
                Some(p) => p,
                None => break,
            };
            if !at_stmt_pos(&masked, p) {
                pos = p + 3;
                continue;
            }
            let (nstart, name) = match next_ident(&masked, p + 3) {
                Some(v) => v,
                None => break,
            };
            let after = skip_ws(&masked, nstart + name.len());
            if after >= masked.len() {
                break;
            }
            match masked[after] {
                ';' => {
                    modules.entry(modpath.to_string()).or_default().submodules.insert(name);
                    pos = after + 1;
                }
                '{' => {
                    let child = if modpath.is_empty() {
                        name.clone()
                    } else {
                        format!("{modpath}::{name}")
                    };
                    let abs_open = seg_start + after;
                    let close = match match_brace(scrubbed, abs_open) {
                        Some(c) => c,
                        None => break,
                    };
                    modules.entry(modpath.to_string()).or_default().submodules.insert(name);
                    walk(rel, scrubbed, abs_open + 1, close, &child, modules, uses);
                    for c in masked
                        .iter_mut()
                        .take(close - seg_start)
                        .skip(after + 1)
                        .filter(|c| **c != '\n')
                    {
                        *c = ' ';
                    }
                    pos = close - seg_start;
                }
                _ => pos = after,
            }
        }
        // items at this level
        for kw in ITEM_KEYWORDS {
            for p in word_positions(&masked, kw) {
                if !at_stmt_pos(&masked, p) {
                    continue;
                }
                let after = skip_ws(&masked, p + kw.len());
                if let Some((_, name)) = next_ident(&masked, after) {
                    // the ident must start right at `after` (no operators
                    // between keyword and name)
                    if after < masked.len() && is_ident_char(masked[after]) {
                        modules.entry(modpath.to_string()).or_default().items.insert(name);
                    }
                }
            }
        }
        for p in word_positions(&masked, "macro_rules") {
            let mut i = p + "macro_rules".len();
            if i < masked.len() && masked[i] == '!' {
                i = skip_ws(&masked, i + 1);
                if let Some(name) = super::lexer::ident_at(&masked, i) {
                    modules.entry(modpath.to_string()).or_default().items.insert(name);
                }
            }
        }
        // use decls at this level
        for p in word_positions(&masked, "use") {
            if !at_stmt_pos(&masked, p) {
                continue;
            }
            let start = skip_ws(&masked, p + 3);
            let mut end = start;
            while end < masked.len() && masked[end] != ';' {
                end += 1;
            }
            if end >= masked.len() {
                continue;
            }
            let tree: String = masked[start..end].iter().collect();
            uses.push(UseDecl {
                rel: rel.to_string(),
                modpath: modpath.to_string(),
                tree: tree.clone(),
                line: line_of(scrubbed, seg_start + p),
            });
            if has_pub_prefix(&masked, p) {
                let m = modules.entry(modpath.to_string()).or_default();
                for (segs, alias) in split_use_tree(&tree) {
                    match segs.last().map(|s| s.as_str()) {
                        Some("*") => m.has_glob_reexport = true,
                        Some(last) => {
                            m.items.insert(alias.unwrap_or_else(|| last.to_string()));
                        }
                        None => {}
                    }
                }
            }
        }
    }
}

/// Build the lib crate's module map from `rust/src`. `""` is the crate
/// root; `main.rs` is tracked as the pseudo-module `__main__`.
pub fn build_crate(tree: &SourceTree) -> (BTreeMap<String, Module>, Vec<UseDecl>) {
    let mut modules = BTreeMap::new();
    let mut uses = Vec::new();
    for (rel, src) in &tree.files {
        let p = match rel.strip_prefix("rust/src/") {
            Some(p) => p,
            None => continue,
        };
        let sc = scrub(src);
        if sc.error.is_some() {
            continue; // the balance pass reports it
        }
        let modpath = if p == "lib.rs" {
            String::new()
        } else if p == "main.rs" {
            "__main__".to_string()
        } else if let Some(stem) = p.strip_suffix("/mod.rs") {
            stem.replace('/', "::")
        } else {
            p.trim_end_matches(".rs").replace('/', "::")
        };
        parse_modules_in_file(rel, &sc.text, &modpath, &mut modules, &mut uses);
    }
    (modules, uses)
}

/// Resolve absolute (crate-rooted) segments. `None` = cannot decide
/// confidently (glob re-exports, unparsed module) — skip.
pub fn resolve_path(modules: &BTreeMap<String, Module>, segs: &[String]) -> Option<bool> {
    let mut cur = String::new();
    for seg in segs {
        let m = match modules.get(&cur) {
            Some(m) if m.parsed => m,
            _ => return None,
        };
        if seg == "*" {
            return Some(true);
        }
        if seg == "self" {
            // `use a::b::{self, X}` — the module resolved so far
            continue;
        }
        if m.submodules.contains(seg) {
            cur = if cur.is_empty() { seg.clone() } else { format!("{cur}::{seg}") };
            continue;
        }
        if m.items.contains(seg) {
            // items may have associated paths (`Enum::Variant` in a use
            // tree); accept the remainder unchecked
            return Some(true);
        }
        if m.has_glob_reexport {
            return None; // the name may come in through the glob
        }
        return Some(false);
    }
    Some(true)
}

pub fn pass_use_resolution(tree: &SourceTree) -> Vec<Finding> {
    let mut out = Vec::new();
    let (modules, uses) = build_crate(tree);
    let allow: BTreeMap<&String, Vec<usize>> = tree
        .files
        .iter()
        .map(|(rel, src)| (rel, allow_lines(src, "use-resolution")))
        .collect();

    // lib/bin sources: crate:: / super:: / self::
    for u in &uses {
        if allow.get(&u.rel).map(|v| v.contains(&u.line)).unwrap_or(false) {
            continue;
        }
        for (segs, _alias) in split_use_tree(&u.tree) {
            let head = match segs.first() {
                Some(h) => h.as_str(),
                None => continue,
            };
            let abs: Vec<String> = match head {
                "crate" => segs[1..].to_vec(),
                "self" => {
                    let mut v: Vec<String> = if u.modpath.is_empty() || u.modpath == "__main__" {
                        Vec::new()
                    } else {
                        u.modpath.split("::").map(String::from).collect()
                    };
                    v.extend(segs[1..].iter().cloned());
                    v
                }
                "super" => {
                    let parts: Vec<String> = if u.modpath.is_empty() || u.modpath == "__main__" {
                        Vec::new()
                    } else {
                        u.modpath.split("::").map(String::from).collect()
                    };
                    let k = segs.iter().take_while(|s| s.as_str() == "super").count();
                    if k > parts.len() {
                        out.push(Finding::new(
                            "A002",
                            "use-resolution",
                            &u.rel,
                            u.line,
                            format!("'{}': too many 'super'", segs.join("::")),
                        ));
                        continue;
                    }
                    let mut v = parts[..parts.len() - k].to_vec();
                    v.extend(segs[k..].iter().cloned());
                    v
                }
                _ => continue, // external crate
            };
            if resolve_path(&modules, &abs) == Some(false) {
                out.push(Finding::new(
                    "A002",
                    "use-resolution",
                    &u.rel,
                    u.line,
                    format!("use path '{}' does not resolve", segs.join("::")),
                ));
            }
        }
    }

    // tests/benches/examples: pawd:: resolves against the lib crate root
    for (rel, src) in &tree.files {
        if rel.starts_with("rust/src/") || !rel.ends_with(".rs") {
            continue;
        }
        let sc = scrub(src);
        if sc.error.is_some() {
            continue;
        }
        let allowed = allow_lines(src, "use-resolution");
        for p in word_positions(&sc.text, "use") {
            if !at_stmt_pos(&sc.text, p) {
                continue;
            }
            let start = skip_ws(&sc.text, p + 3);
            let mut end = start;
            while end < sc.text.len() && sc.text[end] != ';' {
                end += 1;
            }
            if end >= sc.text.len() {
                continue;
            }
            let line = line_of(&sc.text, p);
            if allowed.contains(&line) {
                continue;
            }
            let use_tree: String = sc.text[start..end].iter().collect();
            for (segs, _alias) in split_use_tree(&use_tree) {
                if segs.first().map(|s| s.as_str()) != Some("pawd") {
                    continue;
                }
                if resolve_path(&modules, &segs[1..]) == Some(false) {
                    out.push(Finding::new(
                        "A002",
                        "use-resolution",
                        rel,
                        line,
                        format!("use path '{}' does not resolve", segs.join("::")),
                    ));
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn miri_split_use_tree_nested() {
        let leaves = split_use_tree("a::{b, c as d, e::{f, *}}");
        let flat: Vec<String> = leaves.iter().map(|(s, _)| s.join("::")).collect();
        assert_eq!(flat, vec!["a::b", "a::c", "a::e::f", "a::e::*"]);
        assert_eq!(leaves[1].1.as_deref(), Some("d"));
    }

    #[test]
    fn miri_resolve_through_reexport() {
        let mut modules: BTreeMap<String, Module> = BTreeMap::new();
        let root = modules.entry(String::new()).or_default();
        root.parsed = true;
        root.submodules.insert("a".into());
        let a = modules.entry("a".into()).or_default();
        a.parsed = true;
        a.items.insert("Thing".into());
        let to = |v: &[&str]| v.iter().map(|s| s.to_string()).collect::<Vec<_>>();
        assert_eq!(resolve_path(&modules, &to(&["a", "Thing"])), Some(true));
        assert_eq!(resolve_path(&modules, &to(&["a", "Missing"])), Some(false));
        assert_eq!(resolve_path(&modules, &to(&["a", "self"])), Some(true));
        modules.get_mut("a").unwrap().has_glob_reexport = true;
        assert_eq!(resolve_path(&modules, &to(&["a", "Missing"])), None);
    }
}
