//! Rust-accurate source scrubbing and the `bracket-balance` pass (A001).
//!
//! [`scrub`] blanks comment and string/char-literal *bodies* while keeping
//! length, newlines, and the delimiters themselves, so every later pass can
//! scan for tokens positionally without tripping over `"{"` in a string or
//! `// }` in a comment. Handled: line comments, nested block comments,
//! escapes, raw strings (`r#"…"#`), byte strings (`b"…"`), byte chars
//! (`b'x'`), and the char-literal vs lifetime ambiguity (`'x'` vs `'a`).

use super::{Finding, SourceTree};

/// Outcome of scrubbing one file.
pub struct Scrubbed {
    /// Same length as the input; comment/literal bodies blanked.
    pub text: Vec<char>,
    /// An unterminated construct, as `(line, message)`.
    pub error: Option<(usize, &'static str)>,
}

pub fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

pub fn scrub(src: &str) -> Scrubbed {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut out: Vec<char> = Vec::with_capacity(n);
    let mut i = 0;
    let mut line = 1usize;

    fn blank(out: &mut Vec<char>, c: char) {
        out.push(if c == '\n' { '\n' } else { ' ' });
    }

    while i < n {
        let c = chars[i];
        let nxt = if i + 1 < n { chars[i + 1] } else { '\0' };
        if c == '\n' {
            line += 1;
        }
        // line comment
        if c == '/' && nxt == '/' {
            while i < n && chars[i] != '\n' {
                blank(&mut out, chars[i]);
                i += 1;
            }
            continue;
        }
        // nested block comment
        if c == '/' && nxt == '*' {
            let start = line;
            let mut depth = 0usize;
            while i < n {
                if chars[i] == '\n' {
                    line += 1;
                }
                if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                    depth += 1;
                    blank(&mut out, chars[i]);
                    blank(&mut out, chars[i + 1]);
                    i += 2;
                    continue;
                }
                if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                    depth -= 1;
                    blank(&mut out, chars[i]);
                    blank(&mut out, chars[i + 1]);
                    i += 2;
                    if depth == 0 {
                        break;
                    }
                    continue;
                }
                blank(&mut out, chars[i]);
                i += 1;
            }
            if depth != 0 {
                return Scrubbed { text: out, error: Some((start, "unterminated block comment")) };
            }
            continue;
        }
        let prev = if i > 0 { chars[i - 1] } else { '\0' };
        let prev_is_ident = is_ident_char(prev);
        // raw / byte string openers: r"…", r#"…"#, b"…", br#"…"#
        if !prev_is_ident && (c == 'r' || c == 'b') {
            let mut j = i;
            if c == 'b' && j + 1 < n && chars[j + 1] == 'r' {
                j += 1;
            }
            let mut k = j + 1;
            let mut hashes = 0usize;
            while k < n && chars[k] == '#' && chars[j] != 'b' {
                hashes += 1;
                k += 1;
            }
            let raw = chars[j] == 'r';
            if k < n && chars[k] == '"' && (raw || (c == 'b' && j == i)) {
                let start = line;
                for p in i..=k {
                    out.push(chars[p]);
                }
                i = k + 1;
                let mut closed = false;
                while i < n {
                    if chars[i] == '\n' {
                        line += 1;
                        out.push('\n');
                        i += 1;
                        continue;
                    }
                    if !raw && chars[i] == '\\' && i + 1 < n {
                        blank(&mut out, chars[i]);
                        if chars[i + 1] == '\n' {
                            line += 1;
                            out.push('\n');
                        } else {
                            blank(&mut out, chars[i + 1]);
                        }
                        i += 2;
                        continue;
                    }
                    if chars[i] == '"' {
                        if raw {
                            let mut h = 0usize;
                            while i + 1 + h < n && chars[i + 1 + h] == '#' && h < hashes {
                                h += 1;
                            }
                            if h == hashes {
                                out.push('"');
                                for _ in 0..h {
                                    out.push('#');
                                }
                                i += 1 + h;
                                closed = true;
                                break;
                            }
                            blank(&mut out, chars[i]);
                            i += 1;
                            continue;
                        }
                        out.push('"');
                        i += 1;
                        closed = true;
                        break;
                    }
                    blank(&mut out, chars[i]);
                    i += 1;
                }
                if !closed {
                    return Scrubbed {
                        text: out,
                        error: Some((start, "unterminated string literal")),
                    };
                }
                continue;
            }
        }
        // plain string
        if c == '"' {
            let start = line;
            out.push('"');
            i += 1;
            let mut closed = false;
            while i < n {
                if chars[i] == '\n' {
                    line += 1;
                    out.push('\n');
                    i += 1;
                    continue;
                }
                if chars[i] == '\\' && i + 1 < n {
                    blank(&mut out, chars[i]);
                    if chars[i + 1] == '\n' {
                        line += 1;
                        out.push('\n');
                    } else {
                        blank(&mut out, chars[i + 1]);
                    }
                    i += 2;
                    continue;
                }
                if chars[i] == '"' {
                    out.push('"');
                    i += 1;
                    closed = true;
                    break;
                }
                blank(&mut out, chars[i]);
                i += 1;
            }
            if !closed {
                return Scrubbed { text: out, error: Some((start, "unterminated string literal")) };
            }
            continue;
        }
        // char literal vs lifetime; b'x' byte chars allowed through (the
        // `'` after a `b` that itself follows a non-ident char)
        let byte_char = c == '\''
            && prev == 'b'
            && !(i >= 2 && is_ident_char(chars[i - 2]));
        if c == '\'' && (!prev_is_ident || byte_char) {
            if nxt == '\\' {
                out.push('\'');
                i += 1;
                blank(&mut out, chars[i]); // backslash
                i += 1;
                // the escaped char itself is never the closer (handles '\'')
                if i < n && chars[i] != '\n' {
                    blank(&mut out, chars[i]);
                    i += 1;
                }
                let start = line;
                let mut closed = false;
                while i < n {
                    if chars[i] == '\'' {
                        out.push('\'');
                        i += 1;
                        closed = true;
                        break;
                    }
                    if chars[i] == '\n' {
                        break;
                    }
                    blank(&mut out, chars[i]);
                    i += 1;
                }
                if !closed {
                    return Scrubbed {
                        text: out,
                        error: Some((start, "unterminated char literal")),
                    };
                }
                continue;
            }
            if i + 2 < n && nxt != '\'' && chars[i + 2] == '\'' {
                out.push('\'');
                blank(&mut out, nxt);
                out.push('\'');
                i += 3;
                continue;
            }
            // lifetime — pass through
            out.push(c);
            i += 1;
            continue;
        }
        out.push(c);
        i += 1;
    }
    Scrubbed { text: out, error: None }
}

/// 1-based line of a char offset.
pub fn line_of(text: &[char], offset: usize) -> usize {
    text[..offset.min(text.len())].iter().filter(|&&c| c == '\n').count() + 1
}

/// Line numbers suppressed for `pass_name` via `audit:allow(...)` comments
/// (the comment line and the line after it).
pub fn allow_lines(src: &str, pass_name: &str) -> Vec<usize> {
    let mut allowed = Vec::new();
    for (idx, l) in src.lines().enumerate() {
        if let Some(p) = l.find("audit:allow(") {
            let rest = &l[p + "audit:allow(".len()..];
            if let Some(close) = rest.find(')') {
                if rest[..close].split(',').any(|x| x.trim() == pass_name) {
                    allowed.push(idx + 1);
                    allowed.push(idx + 2);
                }
            }
        }
    }
    allowed
}

/// Next identifier starting at or after `from`; returns `(start, ident)`.
pub fn next_ident(text: &[char], from: usize) -> Option<(usize, String)> {
    let mut i = from;
    while i < text.len() && !is_ident_char(text[i]) {
        i += 1;
    }
    if i >= text.len() {
        return None;
    }
    let start = i;
    let mut s = String::new();
    while i < text.len() && is_ident_char(text[i]) {
        s.push(text[i]);
        i += 1;
    }
    Some((start, s))
}

/// Identifier starting exactly at `i` (`i` must be its first char and not
/// be preceded by an ident char), else None.
pub fn ident_at(text: &[char], i: usize) -> Option<String> {
    if i >= text.len() || !is_ident_char(text[i]) || text[i].is_ascii_digit() {
        return None;
    }
    if i > 0 && is_ident_char(text[i - 1]) {
        return None;
    }
    let mut s = String::new();
    let mut j = i;
    while j < text.len() && is_ident_char(text[j]) {
        s.push(text[j]);
        j += 1;
    }
    Some(s)
}

/// All word-boundary occurrences of `word` in `text`.
pub fn word_positions(text: &[char], word: &str) -> Vec<usize> {
    let w: Vec<char> = word.chars().collect();
    let mut out = Vec::new();
    if w.is_empty() || text.len() < w.len() {
        return out;
    }
    for i in 0..=text.len() - w.len() {
        if text[i..i + w.len()] == w[..]
            && (i == 0 || !is_ident_char(text[i - 1]))
            && (i + w.len() == text.len() || !is_ident_char(text[i + w.len()]))
        {
            out.push(i);
        }
    }
    out
}

/// Skip whitespace forward from `i`.
pub fn skip_ws(text: &[char], mut i: usize) -> usize {
    while i < text.len() && text[i].is_whitespace() {
        i += 1;
    }
    i
}

/// Given the offset of an opening `{`, return the offset of its matching
/// `}` (scrubbed text), or None.
pub fn match_brace(text: &[char], open: usize) -> Option<usize> {
    let mut depth = 0i64;
    for (j, &c) in text.iter().enumerate().skip(open) {
        if c == '{' {
            depth += 1;
        } else if c == '}' {
            depth -= 1;
            if depth == 0 {
                return Some(j);
            }
        }
    }
    None
}

/// Is the token starting at `kw_start` in statement position — preceded
/// (after an optional `pub`/`pub(...)` prefix) by nothing, by `;`/`{`/`}`,
/// or by a newline? Mirrors the Python `(?:^|[;{}]\s*|\n\s*)` anchor.
pub fn at_stmt_pos(text: &[char], kw_start: usize) -> bool {
    let mut i = kw_start;
    // skip back over whitespace; a newline anywhere in the run qualifies
    let mut saw_newline = false;
    loop {
        while i > 0 && text[i - 1].is_whitespace() {
            if text[i - 1] == '\n' {
                saw_newline = true;
            }
            i -= 1;
        }
        if i == 0 {
            return true;
        }
        // consume one pub / pub(...) prefix and keep walking back
        if text[i - 1] == ')' {
            let mut d = 0i64;
            let mut j = i;
            while j > 0 {
                j -= 1;
                if text[j] == ')' {
                    d += 1;
                } else if text[j] == '(' {
                    d -= 1;
                    if d == 0 {
                        break;
                    }
                }
            }
            let before = {
                let mut k = j;
                while k > 0 && text[k - 1].is_whitespace() {
                    k -= 1;
                }
                k
            };
            if before >= 3 && text[before - 3..before] == ['p', 'u', 'b'] {
                i = before - 3;
                saw_newline = false;
                continue;
            }
            return saw_newline;
        }
        if i >= 3 && text[i - 3..i] == ['p', 'u', 'b'] && (i == 3 || !is_ident_char(text[i - 4]))
        {
            i -= 3;
            saw_newline = false;
            continue;
        }
        let prev = text[i - 1];
        return saw_newline || prev == ';' || prev == '{' || prev == '}';
    }
}

/// A001: delimiter balance per file (plus unterminated literals/comments
/// surfaced by the scrubber).
pub fn pass_balance(tree: &SourceTree) -> Vec<Finding> {
    let mut out = Vec::new();
    for (rel, src) in &tree.files {
        if !rel.ends_with(".rs") {
            continue;
        }
        out.extend(balance_one(rel, src));
    }
    out
}

/// Balance-check a single source text (used by the fixture tests too).
pub fn balance_one(rel: &str, src: &str) -> Vec<Finding> {
    let mut out = Vec::new();
    let sc = scrub(src);
    if let Some((line, msg)) = sc.error {
        out.push(Finding::new("A001", "bracket-balance", rel, line, msg.to_string()));
        return out;
    }
    let mut stack: Vec<(char, usize)> = Vec::new();
    let mut line = 1usize;
    for &c in &sc.text {
        match c {
            '\n' => line += 1,
            '(' | '[' | '{' => stack.push((c, line)),
            ')' | ']' | '}' => {
                let want = match c {
                    ')' => '(',
                    ']' => '[',
                    _ => '{',
                };
                match stack.pop() {
                    Some((open, _)) if open == want => {}
                    Some((open, oline)) => {
                        out.push(Finding::new(
                            "A001",
                            "bracket-balance",
                            rel,
                            line,
                            format!("unbalanced '{c}' (open '{open}' from line {oline})"),
                        ));
                        return out;
                    }
                    None => {
                        out.push(Finding::new(
                            "A001",
                            "bracket-balance",
                            rel,
                            line,
                            format!("unbalanced '{c}'"),
                        ));
                        return out;
                    }
                }
            }
            _ => {}
        }
    }
    if let Some((open, oline)) = stack.last() {
        out.push(Finding::new(
            "A001",
            "bracket-balance",
            rel,
            *oline,
            format!("unclosed '{open}'"),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scrub_str(s: &str) -> String {
        scrub(s).text.iter().collect()
    }

    #[test]
    fn miri_scrub_blanks_strings_and_comments() {
        assert_eq!(scrub_str(r#"let x = "a{b"; // }"#), r#"let x = "   ";     "#);
        assert_eq!(scrub_str("a /* { /* [ */ } */ b"), "a                   b");
        // raw string with hashes; brace inside must vanish
        assert_eq!(scrub_str(r##"r#"{"#"##), r##"r#" "#"##);
    }

    #[test]
    fn miri_scrub_char_vs_lifetime() {
        // lifetimes survive, char literals are blanked
        assert_eq!(scrub_str("&'a str"), "&'a str");
        assert_eq!(scrub_str("let c = '{';"), "let c = ' ';");
        assert_eq!(scrub_str(r"let c = '\'';"), "let c = '  ';");
        assert_eq!(scrub_str("m(b'{')"), "m(b' ')");
    }

    #[test]
    fn miri_balance_catches_seeded_imbalance() {
        assert!(balance_one("x.rs", "fn f() { (a + b }").iter().any(|f| f.code == "A001"));
        assert!(balance_one("x.rs", "fn ok() { (a + b) }").is_empty());
        assert!(balance_one("x.rs", "fn f() { \"unterminated").iter().any(|f| f.code == "A001"));
    }

    #[test]
    fn miri_stmt_pos() {
        let t: Vec<char> = "fn a() {}\npub fn b() {}\nlet x = fn_ptr;".chars().collect();
        assert!(at_stmt_pos(&t, 0)); // start
        assert!(at_stmt_pos(&t, 14)); // `fn` after `pub ` at line start
        let call = word_positions(&t, "fn");
        assert_eq!(call.len(), 2); // fn_ptr does not word-match
    }
}
