//! A003 `match-exhaustive`: matches over the declared "grown" enums must
//! either name every variant or carry a catch-all arm.
//!
//! The compiler already enforces exhaustiveness — what it cannot flag is a
//! `_ => {}` arm silently swallowing a variant added three PRs later. This
//! pass inverts the check for enums that keep growing: a match whose arms
//! are all `Enum::…` patterns and that has **no** catch-all must name every
//! declared variant; adding a variant then turns every such site into a
//! finding, exactly like the compiler would if the catch-all were absent.
//! Matches with mixed shapes (`Some(Enum::A)`, tuples, guards on every
//! arm) are skipped — conservatively, since the pass pins zero findings.

use super::lexer::{allow_lines, is_ident_char, line_of, match_brace, scrub, word_positions};
use super::{Finding, SourceTree};
use anyhow::Result;
use std::collections::{BTreeMap, BTreeSet};

/// The grown enums: `(declaring file, enum name)`. Extend this list when a
/// new enum starts accreting variants across PRs.
pub const GROWN_ENUMS: &[(&str, &str)] = &[
    ("rust/src/coordinator/request.rs", "AdminOp"),
    ("rust/src/coordinator/request.rs", "Payload"),
    ("rust/src/coordinator/engine.rs", "Ingress"),
    ("rust/src/delta/compress.rs", "CodecChoice"),
    ("rust/src/net/http.rs", "HttpError"),
];

/// Variant names of `enum_name` declared in `src`, or None if not found.
pub fn enum_variants(src: &str, enum_name: &str) -> Option<Vec<String>> {
    let sc = scrub(src);
    if sc.error.is_some() {
        return None;
    }
    let text = &sc.text;
    for p in word_positions(text, "enum") {
        let mut i = p + 4;
        while i < text.len() && text[i].is_whitespace() {
            i += 1;
        }
        match super::lexer::ident_at(text, i) {
            Some(name) if name == enum_name => {}
            _ => continue,
        }
        // scan to the opening brace (generics allowed, no brace before it)
        let mut j = i + enum_name.len();
        while j < text.len() && text[j] != '{' && text[j] != ';' {
            j += 1;
        }
        if j >= text.len() || text[j] != '{' {
            continue;
        }
        let close = match_brace(text, j)?;
        return Some(variant_names(&text[j + 1..close]));
    }
    None
}

/// Variant names from an enum body: the first identifier after each
/// top-level comma (or the body start), skipping `#[...]` attributes.
fn variant_names(body: &[char]) -> Vec<String> {
    let mut variants = Vec::new();
    let mut j = 0usize;
    let n = body.len();
    let mut d = 0i64;
    let mut at_start = true;
    while j < n {
        let ch = body[j];
        if d == 0 && ch == '#' {
            while j < n && body[j] != '[' {
                j += 1;
            }
            let mut dd = 0i64;
            while j < n {
                if body[j] == '[' {
                    dd += 1;
                } else if body[j] == ']' {
                    dd -= 1;
                    if dd == 0 {
                        break;
                    }
                }
                j += 1;
            }
            j += 1;
            continue;
        }
        match ch {
            '(' | '[' | '{' => d += 1,
            ')' | ']' | '}' => d -= 1,
            ',' if d == 0 => at_start = true,
            c if d == 0 && at_start && (c.is_alphabetic() || c == '_') => {
                let mut name = String::new();
                while j < n && is_ident_char(body[j]) {
                    name.push(body[j]);
                    j += 1;
                }
                variants.push(name);
                at_start = false;
                continue;
            }
            _ => {}
        }
        j += 1;
    }
    variants
}

/// One parsed `match` block: offset of its `{` plus each arm's pattern
/// text (everything left of the top-level `=>`, guard included).
pub struct MatchBlock {
    pub offset: usize,
    pub arm_patterns: Vec<String>,
}

/// Parse every `match` block in scrubbed text.
pub fn iter_matches(text: &[char]) -> Vec<MatchBlock> {
    let n = text.len();
    let mut blocks = Vec::new();
    for m in word_positions(text, "match") {
        let mut i = m + 5;
        let mut depth = 0i64;
        let mut found = None;
        while i < n {
            match text[i] {
                '(' | '[' => depth += 1,
                ')' | ']' => depth -= 1,
                '{' if depth == 0 => {
                    found = Some(i);
                    break;
                }
                ';' if depth == 0 => break,
                _ => {}
            }
            i += 1;
        }
        let block_start = match found {
            Some(b) => b,
            None => continue,
        };
        let mut arms = Vec::new();
        i = block_start + 1;
        'arms: while i < n {
            while i < n && text[i].is_whitespace() {
                i += 1;
            }
            if i >= n || text[i] == '}' {
                break;
            }
            let pat_start = i;
            let mut d = 0i64;
            loop {
                if i >= n {
                    break 'arms;
                }
                match text[i] {
                    '(' | '[' | '{' => d += 1,
                    ')' | ']' => d -= 1,
                    '}' => {
                        if d == 0 {
                            break 'arms; // malformed; bail
                        }
                        d -= 1;
                    }
                    '=' if d == 0 && i + 1 < n && text[i + 1] == '>' => break,
                    _ => {}
                }
                i += 1;
            }
            arms.push(text[pat_start..i].iter().collect::<String>());
            i += 2; // skip =>
            while i < n && text[i].is_whitespace() {
                i += 1;
            }
            if i < n && text[i] == '{' {
                let close = match match_brace(text, i) {
                    Some(c) => c,
                    None => break,
                };
                i = close + 1;
                while i < n && text[i].is_whitespace() {
                    i += 1;
                }
                if i < n && text[i] == ',' {
                    i += 1;
                }
            } else {
                let mut d = 0i64;
                while i < n {
                    match text[i] {
                        '(' | '[' | '{' => d += 1,
                        ')' | ']' | '}' => {
                            if d == 0 {
                                break;
                            }
                            d -= 1;
                        }
                        ',' if d == 0 => {
                            i += 1;
                            break;
                        }
                        _ => {}
                    }
                    i += 1;
                }
            }
        }
        blocks.push(MatchBlock { offset: block_start, arm_patterns: arms });
    }
    blocks
}

/// Is this arm pattern a catch-all: a top-level `_`, `..`, or bare
/// lowercase binding, with no guard?
pub fn pattern_is_catch_all(pat: &str) -> bool {
    let mut p = pat.trim();
    let guarded = p.contains(" if ");
    if guarded {
        p = p.split(" if ").next().unwrap().trim();
    }
    for alt in p.split('|') {
        let mut a = alt.trim();
        for pre in ["ref mut ", "ref ", "mut "] {
            if let Some(rest) = a.strip_prefix(pre) {
                a = rest.trim();
            }
        }
        if guarded {
            continue;
        }
        if a == "_" || a == ".." {
            return true;
        }
        let bare = !a.is_empty()
            && a.chars().next().map(|c| c.is_ascii_lowercase() || c == '_').unwrap_or(false)
            && a.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_');
        if bare && a != "true" && a != "false" {
            return true;
        }
    }
    false
}

/// `Enum::Variant` mentions in a pattern string.
fn variant_mentions(pat: &str, ename: &str) -> BTreeSet<String> {
    let chars: Vec<char> = pat.chars().collect();
    let mut out = BTreeSet::new();
    for p in word_positions(&chars, ename) {
        let mut i = p + ename.len();
        while i < chars.len() && chars[i].is_whitespace() {
            i += 1;
        }
        if i + 1 < chars.len() && chars[i] == ':' && chars[i + 1] == ':' {
            i += 2;
            while i < chars.len() && chars[i].is_whitespace() {
                i += 1;
            }
            if let Some(v) = super::lexer::ident_at(&chars, i) {
                out.insert(v);
            }
        }
    }
    out
}

fn mentions_enum(pat: &str, ename: &str) -> bool {
    let chars: Vec<char> = pat.chars().collect();
    for p in word_positions(&chars, ename) {
        let i = super::lexer::skip_ws(&chars, p + ename.len());
        if i + 1 < chars.len() && chars[i] == ':' && chars[i + 1] == ':' {
            return true;
        }
    }
    false
}

/// Does the arm start with `ename`, `_`, or a bare lowercase ident — the
/// shapes the pass can model?
fn arm_shape_ok(pat: &str, ename: &str) -> bool {
    let t = pat.trim_start();
    if t.starts_with('_') {
        return true;
    }
    let chars: Vec<char> = t.chars().collect();
    match super::lexer::ident_at(&chars, 0) {
        Some(first) => {
            first == ename
                || first.chars().next().map(|c| c.is_ascii_lowercase()).unwrap_or(false)
        }
        None => false,
    }
}

/// Check one scrubbed file against the variant table; used by the repo
/// pass and the fixture tests.
pub fn check_file(
    rel: &str,
    src: &str,
    enums: &BTreeMap<String, BTreeSet<String>>,
) -> Vec<Finding> {
    let mut out = Vec::new();
    let sc = scrub(src);
    if sc.error.is_some() {
        return out;
    }
    let allowed = allow_lines(src, "match-exhaustive");
    for block in iter_matches(&sc.text) {
        if block.arm_patterns.is_empty() {
            continue;
        }
        let lineno = line_of(&sc.text, block.offset);
        if allowed.contains(&lineno) {
            continue;
        }
        for (ename, declared) in enums {
            let mention: Vec<&String> = block
                .arm_patterns
                .iter()
                .filter(|a| mentions_enum(a, ename))
                .collect();
            if mention.is_empty() {
                continue;
            }
            let shaped = block.arm_patterns.iter().all(|a| arm_shape_ok(a, ename));
            let non_catch = block
                .arm_patterns
                .iter()
                .filter(|a| !pattern_is_catch_all(a))
                .count();
            if !shaped || mention.len() != non_catch {
                continue; // mixed shapes — cannot model confidently
            }
            if block.arm_patterns.iter().any(|a| pattern_is_catch_all(a)) {
                continue;
            }
            let mut used = BTreeSet::new();
            for a in &block.arm_patterns {
                used.extend(variant_mentions(a, ename));
            }
            let missing: Vec<&String> = declared.difference(&used).collect();
            if !missing.is_empty() {
                out.push(Finding::new(
                    "A003",
                    "match-exhaustive",
                    rel,
                    lineno,
                    format!(
                        "match over {ename} has no catch-all and misses: {}",
                        missing.iter().map(|s| s.as_str()).collect::<Vec<_>>().join(", ")
                    ),
                ));
            }
        }
    }
    out
}

pub fn pass_match_exhaustive(tree: &SourceTree) -> Result<Vec<Finding>> {
    let mut out = Vec::new();
    let mut enums: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
    for (efile, ename) in GROWN_ENUMS {
        match enum_variants(tree.req(efile)?, ename) {
            Some(v) => {
                enums.insert((*ename).to_string(), v.into_iter().collect());
            }
            None => out.push(Finding::new(
                "A003",
                "match-exhaustive",
                efile,
                1,
                format!("grown enum {ename} not found (audit config stale?)"),
            )),
        }
    }
    for (rel, src) in &tree.files {
        if rel.starts_with("rust/src/")
            || rel.starts_with("rust/tests/")
            || rel.starts_with("rust/benches/")
        {
            out.extend(check_file(rel, src, &enums));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn enums_of(src: &str, name: &str) -> BTreeMap<String, BTreeSet<String>> {
        let mut m = BTreeMap::new();
        m.insert(name.to_string(), enum_variants(src, name).unwrap().into_iter().collect());
        m
    }

    #[test]
    fn miri_enum_variant_parse() {
        let src = "pub enum E { A, B(u32), C { x: u8 }, #[cfg(test)] D, E2 = 5 }";
        assert_eq!(enum_variants(src, "E").unwrap(), vec!["A", "B", "C", "D", "E2"]);
    }

    #[test]
    fn miri_missing_variant_flagged() {
        let decl = "enum E { A, B, C }";
        let bad = "fn f(e: E) { match e { E::A => 1, E::B => 2, } }";
        let good = "fn f(e: E) { match e { E::A => 1, E::B => 2, E::C => 3 } }";
        let catch = "fn f(e: E) { match e { E::A => 1, _ => 2 } }";
        let enums = enums_of(decl, "E");
        let f = check_file("x.rs", bad, &enums);
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].message.contains("misses: C"));
        assert!(check_file("x.rs", good, &enums).is_empty());
        assert!(check_file("x.rs", catch, &enums).is_empty());
    }

    #[test]
    fn miri_mixed_shapes_skipped() {
        let decl = "enum E { A, B, C }";
        let mixed = "fn f(e: Option<E>) { match e { Some(E::A) => 1, None => 2 } }";
        assert!(check_file("x.rs", mixed, &enums_of(decl, "E")).is_empty());
    }
}
