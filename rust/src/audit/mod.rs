//! Repo-native static analysis: the desk-check suite as machine-checked
//! passes.
//!
//! Nine PRs of this codebase were shipped on manual audits — bracket
//! balance, `use`-path resolution, exhaustive-match review, and
//! cross-layer registry diffs (counters ↔ `MetricsSnapshot` ↔ wire keys ↔
//! CLI summaries ↔ README tables). This module codifies those audits as a
//! dependency-free analyzer with a Rust-accurate lexer, run three ways:
//!
//! * `tests/audit_self.rs` — tier-1 test, asserts **zero findings** at HEAD;
//! * `pawd audit [--json] [--root <dir>]` — standalone CLI for CI;
//! * `scripts/audit.py` — a Python mirror with the same passes and codes,
//!   for pre-commit use in containers that have no Rust toolchain
//!   (`scripts/audit.sh` picks whichever is available).
//!
//! Passes and stable finding codes are listed in the README's "Static
//! analysis & sanitizers" section. Suppress a deliberate exception with
//! `// audit:allow(<pass-name>)` on the finding line or the line above.
//!
//! Everything here works on *source text*, not on a compiled AST: the
//! analyzer must run against a tree that does not necessarily compile
//! (that is the point — it runs before the compiler does in toolchain-less
//! containers). Passes are conservative: when a construct cannot be
//! modeled confidently (macro-generated items, glob re-exports, mixed
//! match shapes) the pass skips rather than risk a false positive,
//! because `audit_self` pins the suite to zero findings.

pub mod drift;
pub mod lexer;
pub mod matches;
pub mod unsafety;
pub mod uses;

use crate::util::json::{self, Json};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One analyzer finding. `code` is stable across releases (documented in
/// the README pass table); `pass` is the kebab-case pass name usable in
/// `audit:allow(...)`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    pub code: String,
    pub pass: String,
    /// Repo-root-relative path with `/` separators.
    pub file: String,
    pub line: usize,
    pub message: String,
}

impl Finding {
    pub fn new(code: &str, pass: &str, file: &str, line: usize, message: String) -> Finding {
        Finding {
            code: code.to_string(),
            pass: pass.to_string(),
            file: file.to_string(),
            line,
            message,
        }
    }
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} [{}] {}:{}: {}",
            self.code, self.pass, self.file, self.line, self.message
        )
    }
}

/// Full analyzer output; round-trips through [`crate::util::json`].
#[derive(Clone, Debug, PartialEq)]
pub struct AuditReport {
    pub files_scanned: usize,
    pub findings: Vec<Finding>,
}

impl AuditReport {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("format", json::n(1.0)),
            ("files_scanned", json::n(self.files_scanned as f64)),
            (
                "findings",
                json::arr(
                    self.findings
                        .iter()
                        .map(|f| {
                            json::obj(vec![
                                ("code", json::s(&f.code)),
                                ("pass", json::s(&f.pass)),
                                ("file", json::s(&f.file)),
                                ("line", json::n(f.line as f64)),
                                ("message", json::s(&f.message)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<AuditReport> {
        let mut findings = Vec::new();
        for f in j.req_arr("findings")? {
            findings.push(Finding {
                code: f.req_str("code")?.to_string(),
                pass: f.req_str("pass")?.to_string(),
                file: f.req_str("file")?.to_string(),
                line: f.req_usize("line")?,
                message: f.req_str("message")?.to_string(),
            });
        }
        Ok(AuditReport { files_scanned: j.req_usize("files_scanned")?, findings })
    }
}

/// The audited source tree, loaded once and shared by every pass. Keys are
/// repo-root-relative paths with `/` separators (stable across platforms,
/// matching the golden files and the Python mirror).
pub struct SourceTree {
    pub root: PathBuf,
    pub files: BTreeMap<String, String>,
}

/// Directories (relative to the repo root) whose `.rs` files are audited.
const RS_DIRS: &[&str] = &["rust/src", "rust/tests", "rust/benches", "examples"];
/// Path fragments excluded everywhere — fixtures carry seeded violations,
/// and build output is not source.
const EXCLUDE: &[&str] = &["audit_fixtures", "target"];
/// Non-Rust registry files the drift passes read.
const EXTRA_FILES: &[&str] = &["README.md", "BENCH_baseline.json", "rust/Cargo.toml"];

impl SourceTree {
    pub fn load(root: &Path) -> Result<SourceTree> {
        let mut files = BTreeMap::new();
        for dir in RS_DIRS {
            let base = root.join(dir);
            if base.is_dir() {
                collect_rs(root, &base, &mut files)?;
            }
        }
        for extra in EXTRA_FILES {
            let p = root.join(extra);
            if p.is_file() {
                let text = std::fs::read_to_string(&p)
                    .with_context(|| format!("reading {}", p.display()))?;
                files.insert((*extra).to_string(), text);
            }
        }
        Ok(SourceTree { root: root.to_path_buf(), files })
    }

    /// Required registry file — a drift pass cannot run without it.
    pub fn req(&self, rel: &str) -> Result<&str> {
        self.files
            .get(rel)
            .map(|s| s.as_str())
            .with_context(|| format!("audited tree is missing required file '{rel}'"))
    }

    pub fn rs_file_count(&self) -> usize {
        self.files.keys().filter(|k| k.ends_with(".rs")).count()
    }
}

fn collect_rs(root: &Path, dir: &Path, out: &mut BTreeMap<String, String>) -> Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
        .with_context(|| format!("reading {}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for path in entries {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy().into_owned())
            .collect::<Vec<_>>()
            .join("/");
        if EXCLUDE.iter().any(|x| rel.split('/').any(|seg| seg == *x)) {
            continue;
        }
        if path.is_dir() {
            collect_rs(root, &path, out)?;
        } else if rel.ends_with(".rs") {
            let text = std::fs::read_to_string(&path)
                .with_context(|| format!("reading {}", path.display()))?;
            out.insert(rel, text);
        }
    }
    Ok(())
}

/// Run every pass over the tree rooted at `root`.
pub fn run_repo_audit(root: &Path) -> Result<AuditReport> {
    let tree = SourceTree::load(root)?;
    let mut findings = Vec::new();
    findings.extend(lexer::pass_balance(&tree));
    findings.extend(uses::pass_use_resolution(&tree));
    findings.extend(matches::pass_match_exhaustive(&tree)?);
    findings.extend(drift::pass_counter_drift(&tree)?);
    findings.extend(drift::pass_env_drift(&tree)?);
    findings.extend(drift::pass_route_drift(&tree)?);
    findings.extend(drift::pass_bench_keys(&tree)?);
    findings.extend(unsafety::pass_unsafe(&tree));
    findings.extend(unsafety::pass_condvar(&tree));
    Ok(AuditReport { files_scanned: tree.rs_file_count(), findings })
}

/// Walk up from `start` to the repo root (the directory holding both
/// `rust/Cargo.toml` and `README.md`).
pub fn find_root(start: &Path) -> Option<PathBuf> {
    let mut d = start.to_path_buf();
    if !d.is_absolute() {
        d = std::env::current_dir().ok()?.join(d);
    }
    loop {
        if d.join("rust/Cargo.toml").is_file() && d.join("README.md").is_file() {
            return Some(d);
        }
        if !d.pop() {
            return None;
        }
    }
}

/// CLI entry: `pawd audit [--json] [--root <dir>]`. Returns the number of
/// findings (the CLI maps non-zero to exit status 1).
pub fn cli_audit(args: &[String]) -> Result<usize> {
    let mut as_json = false;
    let mut start = std::env::current_dir()?;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--json" => as_json = true,
            "--root" => {
                let v = it.next().context("--root needs a directory")?;
                start = PathBuf::from(v);
            }
            other => bail!("unknown audit arg '{other}' (expected --json / --root <dir>)"),
        }
    }
    let root = find_root(&start)
        .context("repo root not found (need rust/Cargo.toml + README.md above cwd)")?;
    let report = run_repo_audit(&root)?;
    if as_json {
        println!("{}", report.to_json());
    } else {
        for f in &report.findings {
            println!("{f}");
        }
        println!(
            "audit: {} files, {} finding(s)",
            report.files_scanned,
            report.findings.len()
        );
    }
    Ok(report.findings.len())
}
