//! Unsafe and concurrency hygiene passes.
//!
//! * A201 `unsafe-safety` — every `unsafe` block / `unsafe impl` must carry
//!   a `SAFETY:` comment on its line or immediately above (attributes and
//!   sibling unsafe-impl lines are skipped while walking up); an
//!   `unsafe fn` may instead carry a `# Safety` doc section.
//! * A202 `unsafe-inventory` — per-file unsafe counts are pinned by
//!   `rust/tests/audit_golden/unsafe_inventory.txt`, so each new unsafe
//!   site is a deliberate, reviewable diff.
//! * A203 `condvar-wait-in-loop` — `.wait(..)` / `.wait_timeout(..)` calls
//!   must sit inside a `loop` / `while` / `for` so spurious wakeups re-check
//!   the predicate (`wait_while` is self-predicated and exempt). Lexical,
//!   receiver-agnostic: any non-loop `.wait(` is suspicious enough to flag,
//!   with `audit:allow(condvar-wait-in-loop)` as the escape hatch.

use super::lexer::{allow_lines, line_of, scrub, word_positions};
use super::{Finding, SourceTree};
use std::collections::BTreeMap;

pub const GOLDEN_UNSAFE: &str = "rust/tests/audit_golden/unsafe_inventory.txt";

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum UnsafeKind {
    Block,
    Impl,
    Fn,
}

/// `(line, kind)` of every `unsafe` keyword in a file.
pub fn unsafe_sites(src: &str) -> Vec<(usize, UnsafeKind)> {
    let sc = scrub(src);
    if sc.error.is_some() {
        return Vec::new();
    }
    let text = &sc.text;
    let mut out = Vec::new();
    for p in word_positions(text, "unsafe") {
        let mut i = p + "unsafe".len();
        while i < text.len() && text[i].is_whitespace() {
            i += 1;
        }
        let after: String = text[i..text.len().min(i + 8)].iter().collect();
        let kind = if after.starts_with('{') {
            UnsafeKind::Block
        } else if after.starts_with("impl") {
            UnsafeKind::Impl
        } else if after.starts_with("fn") || after.starts_with("extern") {
            UnsafeKind::Fn
        } else {
            UnsafeKind::Block
        };
        out.push((line_of(text, p), kind));
    }
    out
}

/// SAFETY justification on the site line or an immediately-preceding run
/// of comments / attributes / sibling unsafe-impl lines.
fn has_safety_comment(lines: &[&str], lineno: usize, kind: UnsafeKind) -> bool {
    if lines[lineno - 1].contains("SAFETY") {
        return true;
    }
    let mut i = lineno as i64 - 2;
    let mut seen_comment = false;
    while i >= 0 {
        let l = lines[i as usize].trim();
        if l.starts_with("//") {
            if l.contains("SAFETY") || (kind == UnsafeKind::Fn && l.contains("# Safety")) {
                return true;
            }
            seen_comment = true;
            i -= 1;
            continue;
        }
        if l.starts_with("#[") || l.starts_with("#![") {
            i -= 1;
            continue;
        }
        if l.starts_with("unsafe impl") || l.starts_with("pub unsafe impl") {
            i -= 1;
            continue;
        }
        if l.is_empty() {
            if seen_comment {
                break;
            }
            i -= 1;
            continue;
        }
        break;
    }
    false
}

/// A201 findings for one source text (shared with the fixture tests).
pub fn check_safety_comments(rel: &str, src: &str) -> Vec<Finding> {
    let mut out = Vec::new();
    let lines: Vec<&str> = src.lines().collect();
    let allowed = allow_lines(src, "unsafe-safety");
    for (lineno, kind) in unsafe_sites(src) {
        if allowed.contains(&lineno) {
            continue;
        }
        if !has_safety_comment(&lines, lineno, kind) {
            let kname = match kind {
                UnsafeKind::Block => "block",
                UnsafeKind::Impl => "impl",
                UnsafeKind::Fn => "fn",
            };
            out.push(Finding::new(
                "A201",
                "unsafe-safety",
                rel,
                lineno,
                format!("unsafe {kname} without a SAFETY comment"),
            ));
        }
    }
    out
}

pub fn pass_unsafe(tree: &SourceTree) -> Vec<Finding> {
    let mut out = Vec::new();
    let mut inventory: BTreeMap<&String, usize> = BTreeMap::new();
    for (rel, src) in &tree.files {
        if !rel.starts_with("rust/src/") {
            continue;
        }
        let sites = unsafe_sites(src);
        if !sites.is_empty() {
            inventory.insert(rel, sites.len());
        }
        out.extend(check_safety_comments(rel, src));
    }
    let golden_path = tree.root.join(GOLDEN_UNSAFE);
    let golden_src = match std::fs::read_to_string(&golden_path) {
        Ok(s) => s,
        Err(_) => {
            out.push(Finding::new(
                "A202",
                "unsafe-inventory",
                GOLDEN_UNSAFE,
                1,
                "golden unsafe inventory missing; expected lines '<path> <count>'".to_string(),
            ));
            return out;
        }
    };
    let mut golden: BTreeMap<String, usize> = BTreeMap::new();
    for l in golden_src.lines() {
        let l = l.trim();
        if l.is_empty() || l.starts_with('#') {
            continue;
        }
        if let Some((p, c)) = l.rsplit_once(' ') {
            if let Ok(count) = c.parse::<usize>() {
                golden.insert(p.to_string(), count);
            }
        }
    }
    for (rel, count) in &inventory {
        if golden.get(rel.as_str()).copied() != Some(*count) {
            out.push(Finding::new(
                "A202",
                "unsafe-inventory",
                rel,
                1,
                format!(
                    "{count} unsafe site(s), golden file says {} — update {GOLDEN_UNSAFE} \
                     if the new unsafe is deliberate",
                    golden.get(rel.as_str()).copied().unwrap_or(0)
                ),
            ));
        }
    }
    for rel in golden.keys() {
        if !inventory.keys().any(|k| *k == rel) {
            out.push(Finding::new(
                "A202",
                "unsafe-inventory",
                GOLDEN_UNSAFE,
                1,
                format!("golden file lists '{rel}' but it has no unsafe (or is gone)"),
            ));
        }
    }
    out
}

/// A203 findings for one source text (shared with the fixture tests).
pub fn check_condvar_waits(rel: &str, src: &str) -> Vec<Finding> {
    let mut out = Vec::new();
    let sc = scrub(src);
    if sc.error.is_some() {
        return out;
    }
    let text = &sc.text;
    let n = text.len();
    let allowed = allow_lines(src, "condvar-wait-in-loop");
    // precompute open-brace stack positions for each index on demand
    for p in word_positions(text, "wait").into_iter().chain(word_positions(text, "wait_timeout"))
    {
        if p == 0 || text[p - 1] != '.' {
            continue;
        }
        // `.wait` must be followed directly by `(` (after ws); this skips
        // `.wait_while` (self-predicated) and unrelated `.wait_for`-style
        // names because `wait` only word-matches when not followed by `_`
        let word_len = if text[p..n.min(p + "wait_timeout".len())]
            .iter()
            .collect::<String>()
            .starts_with("wait_timeout")
        {
            "wait_timeout".len()
        } else {
            "wait".len()
        };
        let mut i = p + word_len;
        while i < n && text[i].is_whitespace() {
            i += 1;
        }
        if i >= n || text[i] != '(' {
            continue;
        }
        let lineno = line_of(text, p);
        if allowed.contains(&lineno) {
            continue;
        }
        // collect enclosing open braces, innermost last
        let mut opens: Vec<usize> = Vec::new();
        for (j, &c) in text.iter().enumerate().take(p) {
            if c == '{' {
                opens.push(j);
            } else if c == '}' {
                opens.pop();
            }
        }
        let mut in_loop = false;
        for &open_pos in &opens {
            let head_start = open_pos.saturating_sub(240);
            let head: String = text[head_start..open_pos].iter().collect();
            // strip back to the nearest statement boundary, then look for
            // a loop keyword heading this block
            let cut = ["{", "}", ";"]
                .iter()
                .filter_map(|d| head.rfind(*d))
                .max()
                .map(|c| c + 1)
                .unwrap_or(0);
            let head_chars: Vec<char> = head[cut..].chars().collect();
            if !word_positions(&head_chars, "loop").is_empty()
                || !word_positions(&head_chars, "while").is_empty()
                || !word_positions(&head_chars, "for").is_empty()
            {
                in_loop = true;
                break;
            }
        }
        if !in_loop {
            out.push(Finding::new(
                "A203",
                "condvar-wait-in-loop",
                rel,
                lineno,
                "condvar wait outside any loop — spurious wakeups will break the \
                 predicate (re-check in a while/loop)"
                    .to_string(),
            ));
        }
    }
    out
}

pub fn pass_condvar(tree: &SourceTree) -> Vec<Finding> {
    let mut out = Vec::new();
    for (rel, src) in &tree.files {
        if rel.starts_with("rust/src/") || rel.starts_with("rust/tests/") {
            out.extend(check_condvar_waits(rel, src));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn miri_unsafe_sites_classified() {
        let src = "unsafe impl Send for X {}\nfn f() {\n    // SAFETY: fine\n    \
                   unsafe { g() }\n}\nunsafe fn g() {}\n";
        let sites = unsafe_sites(src);
        assert_eq!(sites.len(), 3);
        assert_eq!(sites[0], (1, UnsafeKind::Impl));
        assert_eq!(sites[1], (4, UnsafeKind::Block));
        assert_eq!(sites[2], (6, UnsafeKind::Fn));
    }

    #[test]
    fn miri_safety_comment_required() {
        let bad = "fn f() {\n    unsafe { g() }\n}\n";
        let good = "fn f() {\n    // SAFETY: g is fine here\n    unsafe { g() }\n}\n";
        let fn_doc = "/// Does things.\n///\n/// # Safety\n///\n/// Caller checks cpu.\n\
                      #[target_feature(enable = \"avx2\")]\nunsafe fn g() {}\n";
        assert_eq!(check_safety_comments("x.rs", bad).len(), 1);
        assert!(check_safety_comments("x.rs", good).is_empty());
        assert!(check_safety_comments("x.rs", fn_doc).is_empty());
        let allowed = "fn f() {\n    // audit:allow(unsafe-safety)\n    unsafe { g() }\n}\n";
        assert!(check_safety_comments("x.rs", allowed).is_empty());
    }

    #[test]
    fn miri_condvar_wait_needs_loop() {
        let bad = "fn f() {\n    let g = cv.wait(g).unwrap();\n}\n";
        let good = "fn f() {\n    while !*done {\n        g = cv.wait(g).unwrap();\n    }\n}\n";
        let l = "fn f() {\n    loop {\n        let (ng, t) = cv.wait_timeout(g, d).unwrap();\n\
                 \x20       if t.timed_out() { break; }\n    }\n}\n";
        let wait_while = "fn f() {\n    let g = cv.wait_while(g, |s| !s.done).unwrap();\n}\n";
        assert_eq!(check_condvar_waits("x.rs", bad).len(), 1);
        assert!(check_condvar_waits("x.rs", good).is_empty());
        assert!(check_condvar_waits("x.rs", l).is_empty());
        assert!(check_condvar_waits("x.rs", wait_while).is_empty());
    }
}
