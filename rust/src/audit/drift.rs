//! Registry drift passes (A101–A104): parse the source-of-truth registry
//! out of each layer and pin the layers to each other and to the README.
//!
//! * A101 `counter-drift` — `exec::counters` getters == `MetricsSnapshot`
//!   fields == wire JSON keys (both codec directions) == `snap.<name>`
//!   CLI summary references == README "Counter registry" table.
//! * A102 `env-drift` — `PAWD_*` env reads anywhere == README
//!   "Environment knobs" table (both directions).
//! * A103 `route-drift` — `AdminOp` variants (kebab-cased) ==
//!   `admin_routes` consts == `ALL` == README `/v1/admin/<op>` row.
//!   This is the PR 8 drift unit test promoted into the analyzer.
//! * A104 `bench-key-drift` — every gated (`*per_s`) key in
//!   `BENCH_baseline.json` is emitted by a registered bench binary.

use super::lexer::{ident_at, line_of, match_brace, scrub, skip_ws, word_positions};
use super::matches::enum_variants;
use super::{Finding, SourceTree};
use crate::util::json::Json;
use anyhow::Result;
use std::collections::{BTreeMap, BTreeSet};

const COUNTERS_RS: &str = "rust/src/exec/counters.rs";
const METRICS_RS: &str = "rust/src/coordinator/metrics.rs";
const WIRE_RS: &str = "rust/src/net/wire.rs";
const MAIN_RS: &str = "rust/src/main.rs";
const REQUEST_RS: &str = "rust/src/coordinator/request.rs";

/// Counter getter names: `pub fn <name>() -> u64` in `exec/counters.rs`
/// (excluding `reset`).
pub fn counter_getters(counters_src: &str) -> Vec<String> {
    let sc: String = scrub(counters_src).text.iter().collect();
    let mut out = Vec::new();
    for line in sc.lines() {
        if let Some(p) = line.find("pub fn ") {
            let rest = &line[p + "pub fn ".len()..];
            let name: String = rest
                .chars()
                .take_while(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || *c == '_')
                .collect();
            if !name.is_empty() && rest[name.len()..].starts_with("() -> u64") {
                out.push(name);
            }
        }
    }
    out
}

/// `pub <name>:` field names of `struct <name> { .. }`.
pub fn struct_fields(src: &str, struct_name: &str) -> Option<Vec<String>> {
    let sc = scrub(src);
    if sc.error.is_some() {
        return None;
    }
    let text = &sc.text;
    for p in word_positions(text, "struct") {
        let i = skip_ws(text, p + "struct".len());
        match ident_at(text, i) {
            Some(name) if name == struct_name => {}
            _ => continue,
        }
        let mut j = i + struct_name.len();
        while j < text.len() && text[j] != '{' && text[j] != ';' {
            j += 1;
        }
        if j >= text.len() || text[j] != '{' {
            continue;
        }
        let close = match_brace(text, j)?;
        let body = &text[j + 1..close];
        let mut fields = Vec::new();
        for q in word_positions(body, "pub") {
            let s = skip_ws(body, q + 3);
            if let Some(name) = ident_at(body, s) {
                let after = skip_ws(body, s + name.len());
                if after < body.len()
                    && body[after] == ':'
                    && body.get(after + 1) != Some(&':')
                {
                    fields.push(name);
                }
            }
        }
        return Some(fields);
    }
    None
}

/// First-column backticked names of the first markdown table after a
/// heading containing `heading_fragment`; None if no such table.
pub fn readme_table(readme: &str, heading_fragment: &str) -> Option<BTreeSet<String>> {
    let lines: Vec<&str> = readme.lines().collect();
    let h = lines
        .iter()
        .position(|l| l.starts_with('#') && l.contains(heading_fragment))?;
    let mut names = BTreeSet::new();
    let mut in_table = false;
    for l in &lines[h + 1..] {
        if l.starts_with('#') {
            break;
        }
        if l.starts_with('|') {
            in_table = true;
            let rest = l[1..].trim_start();
            if let Some(cell) = rest.strip_prefix('`') {
                if let Some(end) = cell.find('`') {
                    let name = &cell[..end];
                    if !name.is_empty()
                        && name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_')
                    {
                        names.insert(name.to_string());
                    }
                }
            }
        } else if in_table && l.trim().is_empty() {
            break;
        }
    }
    if in_table {
        Some(names)
    } else {
        None
    }
}

pub fn pass_counter_drift(tree: &SourceTree) -> Result<Vec<Finding>> {
    let mut out = Vec::new();
    let mut f = |file: &str, line: usize, msg: String| {
        out.push(Finding::new("A101", "counter-drift", file, line, msg));
    };
    let counters: Vec<String> = counter_getters(tree.req(COUNTERS_RS)?)
        .into_iter()
        .filter(|c| c != "reset")
        .collect();
    let metrics_src = tree.req(METRICS_RS)?;
    let fields = match struct_fields(metrics_src, "MetricsSnapshot") {
        Some(fl) => fl,
        None => {
            f(METRICS_RS, 1, "MetricsSnapshot struct not found".to_string());
            return Ok(out);
        }
    };
    let metrics_scrubbed: String = scrub(metrics_src).text.iter().collect();
    for c in &counters {
        if !fields.contains(c) {
            f(
                METRICS_RS,
                1,
                format!("counter '{c}' (exec/counters.rs) has no MetricsSnapshot field"),
            );
        }
        if !metrics_scrubbed.contains(&format!("counters::{c}()")) {
            f(
                METRICS_RS,
                1,
                format!("counter '{c}' is never read into the snapshot (snapshot_inner)"),
            );
        }
    }
    let wire_src = tree.req(WIRE_RS)?;
    for field in &fields {
        let needle = format!("\"{field}\"");
        if wire_src.matches(&needle).count() < 2 {
            f(
                WIRE_RS,
                1,
                format!(
                    "MetricsSnapshot field '{field}' missing from the wire codec \
                     (need both snapshot_to_json and snapshot_from_json)"
                ),
            );
        }
    }
    let main_src = tree.req(MAIN_RS)?;
    let main_chars: Vec<char> = main_src.chars().collect();
    let mut snap_refs = BTreeSet::new();
    for p in word_positions(&main_chars, "snap") {
        let i = p + 4;
        if main_chars.get(i) == Some(&'.') {
            if let Some(name) = ident_at(&main_chars, i + 1) {
                snap_refs.insert(name.clone());
                if !fields.contains(&name) {
                    f(
                        MAIN_RS,
                        line_of(&main_chars, p),
                        format!("serve summary references unknown snapshot field '{name}'"),
                    );
                }
            }
        }
    }
    for c in &counters {
        if !snap_refs.contains(c) {
            f(
                MAIN_RS,
                1,
                format!("counter '{c}' is not surfaced in any CLI summary line (snap.{c})"),
            );
        }
    }
    let readme = tree.req("README.md")?;
    let table = match readme_table(readme, "Counter registry") {
        Some(t) => t,
        None => {
            f(
                "README.md",
                1,
                "README counter table ('Counter registry' heading) not found".to_string(),
            );
            return Ok(out);
        }
    };
    for c in &counters {
        if !table.contains(c) {
            f("README.md", 1, format!("counter '{c}' missing from the README counter table"));
        }
    }
    for name in &table {
        if !counters.contains(name) {
            f("README.md", 1, format!("README counter table lists unknown counter '{name}'"));
        }
    }
    Ok(out)
}

/// `PAWD_*` names read via `env::var` / `env::var_os` in `src`, with the
/// first read site of each.
pub fn env_reads(src: &str) -> Vec<(String, usize)> {
    let chars: Vec<char> = src.chars().collect();
    let mut out = Vec::new();
    let mut search = 0usize;
    let hay: String = chars.iter().collect();
    while let Some(rel_p) = hay[search..].find("env::var") {
        let p = search + rel_p;
        search = p + "env::var".len();
        let mut i = search;
        if hay[i..].starts_with("_os") {
            i += 3;
        }
        let rest: Vec<char> = chars[i..].to_vec();
        let mut j = skip_ws(&rest, 0);
        if rest.get(j) != Some(&'(') {
            continue;
        }
        j = skip_ws(&rest, j + 1);
        if rest.get(j) != Some(&'"') {
            continue;
        }
        j += 1;
        let mut name = String::new();
        while j < rest.len() && rest[j] != '"' {
            name.push(rest[j]);
            j += 1;
        }
        if name.starts_with("PAWD_") {
            out.push((name, line_of(&chars, p)));
        }
    }
    out
}

pub fn pass_env_drift(tree: &SourceTree) -> Result<Vec<Finding>> {
    let mut out = Vec::new();
    let mut reads: BTreeMap<String, (String, usize)> = BTreeMap::new();
    for (rel, src) in &tree.files {
        if !rel.ends_with(".rs") {
            continue;
        }
        for (name, line) in env_reads(src) {
            reads.entry(name).or_insert_with(|| (rel.clone(), line));
        }
    }
    let readme = tree.req("README.md")?;
    let table = match readme_table(readme, "Environment knobs") {
        Some(t) => t,
        None => {
            out.push(Finding::new(
                "A102",
                "env-drift",
                "README.md",
                1,
                "README env table ('Environment knobs' heading) not found".to_string(),
            ));
            return Ok(out);
        }
    };
    for (var, (rel, line)) in &reads {
        if !table.contains(var) {
            out.push(Finding::new(
                "A102",
                "env-drift",
                rel,
                *line,
                format!("env var '{var}' read here but missing from the README env table"),
            ));
        }
    }
    for var in &table {
        if var.starts_with("PAWD_") && !reads.contains_key(var) {
            out.push(Finding::new(
                "A102",
                "env-drift",
                "README.md",
                1,
                format!("README env table lists '{var}' but nothing reads it"),
            ));
        }
    }
    Ok(out)
}

/// CamelCase → kebab-case (`PublishIncremental` → `publish-incremental`).
pub fn kebab(name: &str) -> String {
    let mut out = String::new();
    for (i, c) in name.chars().enumerate() {
        if c.is_ascii_uppercase() && i > 0 {
            out.push('-');
        }
        out.push(c.to_ascii_lowercase());
    }
    out
}

pub fn pass_route_drift(tree: &SourceTree) -> Result<Vec<Finding>> {
    let mut out = Vec::new();
    let mut f = |file: &str, msg: String| {
        out.push(Finding::new("A103", "route-drift", file, 1, msg));
    };
    let variants = match enum_variants(tree.req(REQUEST_RS)?, "AdminOp") {
        Some(v) => v,
        None => {
            f(REQUEST_RS, "AdminOp enum not found".to_string());
            return Ok(out);
        }
    };
    let wire_src = tree.req(WIRE_RS)?;
    let wire_scrubbed: String = scrub(wire_src).text.iter().collect();
    let chars: Vec<char> = wire_scrubbed.chars().collect();
    let (body, body_lines) = match wire_scrubbed.find("pub mod admin_routes") {
        Some(p) => {
            let open = (p..chars.len()).find(|&i| chars[i] == '{');
            match open.and_then(|o| match_brace(&chars, o).map(|c| (o, c))) {
                Some((o, c)) => (
                    chars[o..c].iter().collect::<String>(),
                    (line_of(&chars, o), line_of(&chars, c)),
                ),
                None => {
                    f(WIRE_RS, "admin_routes module not found".to_string());
                    return Ok(out);
                }
            }
        }
        None => {
            f(WIRE_RS, "admin_routes module not found".to_string());
            return Ok(out);
        }
    };
    // consts: `pub const NAME: &str = "value";` — values live in the raw
    // source (the scrubbed copy blanks string bodies), restricted to the
    // admin_routes module's line window
    let mut consts: BTreeMap<String, String> = BTreeMap::new();
    for (lineno, line) in wire_src.lines().enumerate() {
        if lineno + 1 < body_lines.0 || lineno + 1 > body_lines.1 {
            continue;
        }
        let t = line.trim();
        if let Some(rest) = t.strip_prefix("pub const ") {
            if let Some(colon) = rest.find(": &str = \"") {
                let name = &rest[..colon];
                let val_start = colon + ": &str = \"".len();
                if let Some(end) = rest[val_start..].find('"') {
                    if name.chars().all(|c| c.is_ascii_uppercase() || c == '_') {
                        let val = rest[val_start..val_start + end].to_string();
                        consts.insert(name.to_string(), val);
                    }
                }
            }
        }
    }
    let all_decl = body.find("pub const ALL: [&str; ");
    let (all_count, all_names) = match all_decl {
        Some(p) => {
            let rest = &body[p + "pub const ALL: [&str; ".len()..];
            let count: usize = rest
                .chars()
                .take_while(|c| c.is_ascii_digit())
                .collect::<String>()
                .parse()
                .unwrap_or(0);
            let open = rest.find('[').map(|o| p + "pub const ALL: [&str; ".len() + o);
            let names = match open {
                Some(o) => {
                    let seg = &body[o..body[o..].find(']').map(|e| o + e).unwrap_or(body.len())];
                    let chars: Vec<char> = seg.chars().collect();
                    let mut names = Vec::new();
                    let mut i = 0;
                    while i < chars.len() {
                        if chars[i].is_ascii_uppercase()
                            && (i == 0 || !super::lexer::is_ident_char(chars[i - 1]))
                        {
                            let mut name = String::new();
                            while i < chars.len()
                                && (chars[i].is_ascii_uppercase() || chars[i] == '_')
                            {
                                name.push(chars[i]);
                                i += 1;
                            }
                            names.push(name);
                        } else {
                            i += 1;
                        }
                    }
                    names
                }
                None => Vec::new(),
            };
            (count, names)
        }
        None => {
            f(WIRE_RS, "admin_routes::ALL not found".to_string());
            return Ok(out);
        }
    };
    let expect: BTreeSet<String> = variants.iter().map(|v| kebab(v)).collect();
    let got: BTreeSet<String> = consts.values().cloned().collect();
    for r in expect.difference(&got) {
        f(WIRE_RS, format!("AdminOp variant route '{r}' has no admin_routes const"));
    }
    for r in got.difference(&expect) {
        f(WIRE_RS, format!("admin_routes const '{r}' matches no AdminOp variant"));
    }
    if all_count != variants.len() || all_names.len() != variants.len() {
        f(
            WIRE_RS,
            format!(
                "admin_routes::ALL has {} entries (declared {}), AdminOp has {} variants",
                all_names.len(),
                all_count,
                variants.len()
            ),
        );
    }
    let mut all_sorted: Vec<String> =
        all_names.iter().cloned().collect::<BTreeSet<_>>().into_iter().collect();
    all_sorted.sort();
    let mut const_names: Vec<String> = consts.keys().cloned().collect();
    const_names.sort();
    if all_sorted != const_names {
        f(WIRE_RS, "admin_routes::ALL does not list every const exactly once".to_string());
    }
    let readme = tree.req("README.md")?;
    let row = readme.lines().find(|l| l.contains("/v1/admin/<op>"));
    match row {
        None => f("README.md", "README route table has no /v1/admin/<op> row".to_string()),
        Some(row) => {
            for r in &got {
                if !row.contains(&format!("`{r}`")) {
                    f("README.md", format!("README admin route row does not mention `{r}`"));
                }
            }
        }
    }
    Ok(out)
}

pub fn pass_bench_keys(tree: &SourceTree) -> Result<Vec<Finding>> {
    let mut out = Vec::new();
    let baseline_src = match tree.files.get("BENCH_baseline.json") {
        Some(s) => s,
        None => return Ok(out), // no baseline, nothing to pin
    };
    let baseline = match Json::parse(baseline_src) {
        Ok(j) => j,
        Err(e) => {
            out.push(Finding::new(
                "A104",
                "bench-key-drift",
                "BENCH_baseline.json",
                1,
                format!("unreadable: {e:?}"),
            ));
            return Ok(out);
        }
    };
    let cargo = tree.req("rust/Cargo.toml")?;
    let mut registered = BTreeSet::new();
    for line in cargo.lines() {
        let t = line.trim();
        if let Some(rest) = t.strip_prefix("name = \"") {
            if let Some(end) = rest.find('"') {
                registered.insert(rest[..end].to_string());
            }
        }
    }
    let bench_src: String = tree
        .files
        .iter()
        .filter(|(rel, _)| rel.starts_with("rust/benches/"))
        .map(|(_, s)| s.as_str())
        .collect::<Vec<_>>()
        .join("\n");
    let scenarios = match baseline.get("scenarios").and_then(|s| s.as_obj()) {
        Some(s) => s,
        None => return Ok(out),
    };
    for (scenario, metrics) in scenarios {
        let bench = scenario.split('/').next().unwrap_or("");
        if !registered.contains(bench)
            || !tree.files.contains_key(&format!("rust/benches/{bench}.rs"))
        {
            out.push(Finding::new(
                "A104",
                "bench-key-drift",
                "BENCH_baseline.json",
                1,
                format!("baseline scenario '{scenario}' names no registered bench"),
            ));
            continue;
        }
        let metrics = match metrics.as_obj() {
            Some(m) => m,
            None => continue,
        };
        for metric in metrics.keys() {
            if !metric.ends_with("per_s") {
                continue;
            }
            if bench_src.contains(metric.as_str()) {
                continue;
            }
            // dynamic keys like `lowrank_r2_per_s`: strip digit runs and
            // require every remaining piece to appear
            let pieces: Vec<&str> = metric
                .split(|c: char| c.is_ascii_digit())
                .filter(|p| p.len() > 2)
                .collect();
            if !pieces.is_empty() && pieces.iter().all(|p| bench_src.contains(p)) {
                continue;
            }
            out.push(Finding::new(
                "A104",
                "bench-key-drift",
                "BENCH_baseline.json",
                1,
                format!("gated key '{scenario}:{metric}' not emitted by any bench source"),
            ));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn miri_counter_getter_parse() {
        let src = "pub fn base_gemms() -> u64 { X.load(O) }\npub fn reset() { }\n\
                   fn private_helper() -> u64 { 0 }\npub fn wire_bytes() -> u64 { 0 }";
        assert_eq!(counter_getters(src), vec!["base_gemms", "wire_bytes"]);
    }

    #[test]
    fn miri_struct_fields_and_kebab() {
        let src = "pub struct S { pub a: u64, b: u64, pub c_d: Vec<u8> }";
        assert_eq!(struct_fields(src, "S").unwrap(), vec!["a", "c_d"]);
        assert_eq!(kebab("PublishIncremental"), "publish-incremental");
        assert_eq!(kebab("Gc"), "gc");
    }

    #[test]
    fn miri_readme_table_parse() {
        let md = "## Counter registry\n\nintro\n\n| Counter | Meaning |\n| --- | --- |\n\
                  | `a_b` | stuff |\n| `c` | more |\n\n## Next\n";
        let t = readme_table(md, "Counter registry").unwrap();
        assert_eq!(t.into_iter().collect::<Vec<_>>(), vec!["a_b", "c"]);
        assert!(readme_table(md, "Nonexistent").is_none());
    }

    #[test]
    fn miri_env_read_scan() {
        let src = "let a = std::env::var(\"PAWD_X\");\nlet b = env::var_os ( \"PAWD_Y\" );\n\
                   let c = env::var(\"HOME\");";
        let reads = env_reads(src);
        assert_eq!(reads.len(), 2);
        assert_eq!(reads[0], ("PAWD_X".to_string(), 1));
        assert_eq!(reads[1], ("PAWD_Y".to_string(), 2));
    }
}
