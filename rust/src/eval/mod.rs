//! Evaluation harness: zero-shot multiple-choice accuracy (lm-eval-harness
//! scoring rule), perplexity, and teacher-fidelity metrics.

pub mod fidelity;
pub mod harness;

pub use fidelity::{codec_shootout, render_shootout, ModuleShootout, ShootoutRow};
pub use harness::{evaluate_suite, mc_accuracy, SuiteResult};
