//! Evaluation harness: zero-shot multiple-choice accuracy (lm-eval-harness
//! scoring rule), perplexity, and teacher-fidelity metrics.

pub mod fidelity;
pub mod harness;

pub use harness::{evaluate_suite, mc_accuracy, SuiteResult};
