//! Teacher-fidelity metrics: how closely a compressed student reproduces
//! the fine-tuned teacher's *behaviour* (the paper's "preserve the function
//! the network computes" objective, §2 "Prior evidence against weight
//! reconstruction") — plus the per-module codec shoot-out harness
//! ([`codec_shootout`]): reconstruction error vs artifact bytes vs fused
//! throughput for every registered [`DeltaCodec`](crate::delta::DeltaCodec).

use crate::delta::cache::build_layer_caches;
use crate::delta::codec::codec_for;
use crate::delta::compress::CompressOptions;
use crate::delta::types::{Axis, CodecKind, DeltaModule};
use crate::exec::{FusedDeltaLinear, LinearOp};
use crate::model::{FlatParams, ModuleId, Transformer};
use crate::tensor::ops::log_softmax_into;
use crate::tensor::Tensor2;

/// Fidelity of `student` against `teacher` measured on a set of documents.
#[derive(Clone, Debug, Default)]
pub struct Fidelity {
    /// Mean squared error between logits.
    pub logit_mse: f64,
    /// Mean KL(teacher ‖ student) per token (nats).
    pub kl: f64,
    /// Fraction of positions where the argmax token agrees.
    pub agreement: f64,
    pub n_tokens: usize,
}

pub fn fidelity(
    tf: &Transformer,
    teacher: &FlatParams,
    student: &FlatParams,
    docs: &[Vec<u8>],
) -> Fidelity {
    let vocab = tf.cfg.vocab;
    let mut mse = 0f64;
    let mut kl = 0f64;
    let mut agree = 0usize;
    let mut n = 0usize;
    let mut lt = vec![0f32; vocab];
    let mut ls = vec![0f32; vocab];
    for doc in docs {
        if doc.len() < 2 {
            continue;
        }
        let t_logits = tf.forward_one(teacher, doc);
        let s_logits = tf.forward_one(student, doc);
        for pos in 0..doc.len() {
            let (tr, sr) = (t_logits.row(pos), s_logits.row(pos));
            let mut row_mse = 0f64;
            for (a, b) in tr.iter().zip(sr) {
                let d = (a - b) as f64;
                row_mse += d * d;
            }
            mse += row_mse / vocab as f64;
            log_softmax_into(tr, &mut lt);
            log_softmax_into(sr, &mut ls);
            let mut row_kl = 0f64;
            for (a, b) in lt.iter().zip(&ls) {
                row_kl += (a.exp() as f64) * ((a - b) as f64);
            }
            kl += row_kl;
            let t_arg = argmax(tr);
            let s_arg = argmax(sr);
            if t_arg == s_arg {
                agree += 1;
            }
            n += 1;
        }
    }
    if n == 0 {
        return Fidelity::default();
    }
    Fidelity {
        logit_mse: mse / n as f64,
        kl: kl / n as f64,
        agreement: agree as f64 / n as f64,
        n_tokens: n,
    }
}

/// One codec's measurements for one module in the shoot-out.
#[derive(Clone, Debug)]
pub struct ShootoutRow {
    pub kind: CodecKind,
    /// Residual rank for [`CodecKind::LowRank`] rows (the sweep emits one
    /// row per rank); `None` for rank-free codecs.
    pub rank: Option<usize>,
    /// Held-out validation MSE of the reconstructed module.
    pub val_mse: f64,
    /// Packed artifact bytes for this module.
    pub payload_bytes: u64,
    /// Fused single-module forward throughput (activation rows / second).
    pub fused_rows_per_s: f64,
}

impl ShootoutRow {
    /// Codec label with the sweep rank appended for lowrank rows
    /// (e.g. `lowrank@4`).
    pub fn label(&self) -> String {
        match self.rank {
            Some(r) => format!("{}@{r}", self.kind.label()),
            None => self.kind.label().to_string(),
        }
    }
}

/// Shoot-out verdict for one module: every codec's row plus the kind the
/// calibration-error-driven selector would publish.
#[derive(Clone, Debug)]
pub struct ModuleShootout {
    pub id: ModuleId,
    pub rows: Vec<ShootoutRow>,
    pub selected: CodecKind,
    /// Rank of the selected row when it is a lowrank row (always the
    /// configured [`CompressOptions::lowrank_rank`] — sweep rows at other
    /// ranks are informational and never selected).
    pub selected_rank: Option<usize>,
}

impl ModuleShootout {
    /// The row the selector picked — the codec (and rank) `auto` would
    /// publish for this module.
    pub fn selected_row(&self) -> &ShootoutRow {
        self.rows
            .iter()
            .find(|r| r.kind == self.selected && r.rank == self.selected_rank)
            .expect("selected row is always present")
    }
}

/// Time a fused forward through one packed module (rows/second over a
/// deterministic activation batch). Wall-clock, so treat as indicative.
fn fused_rows_per_s(w_base: &[f32], m: &DeltaModule, iters: usize) -> f64 {
    let rows = 32;
    let d_in = m.d_in();
    let mut x = Tensor2::zeros(rows, d_in);
    for (i, v) in x.data.iter_mut().enumerate() {
        *v = ((i % 37) as f32 - 18.0) * 0.05;
    }
    let lin = FusedDeltaLinear::new(w_base, m);
    let mut y = lin.forward(&x); // warm-up + output reuse
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        lin.forward_into(&x, &mut y);
    }
    let secs = t0.elapsed().as_secs_f64().max(1e-9);
    (rows * iters) as f64 / secs
}

/// Run the per-module codec shoot-out over every patchable module: encode
/// under each registered codec, measure held-out reconstruction error,
/// packed bytes, and fused throughput, and record which codec the `auto`
/// selector would publish.
///
/// The per-axis slate is extended with [`Axis::Scalar`] so its validation
/// MSE is a minimum over a superset of the scalar codec's single candidate
/// — per-axis ≤ scalar therefore holds on every calibrated module by
/// construction of the selection rule (they share the same val shard).
/// Selection keeps per-axis unless a challenger is strictly better.
///
/// The lowrank codec is swept over ranks `{2, 4, 8}` plus the configured
/// [`CompressOptions::lowrank_rank`] (one row per rank, tagged via
/// [`ShootoutRow::rank`]) so the bytes-vs-MSE trade of the residual rank
/// is visible per module. Only the configured-rank row is eligible for
/// selection — it is what `publish` would actually ship.
pub fn codec_shootout(
    base: &FlatParams,
    finetuned: &FlatParams,
    calib_docs: &[Vec<u8>],
    opts: &CompressOptions,
) -> Vec<ModuleShootout> {
    let cfg = base.cfg().clone();
    let tf = Transformer::new(&cfg);
    let mut pa_opts = opts.clone();
    if !pa_opts.axes.contains(&Axis::Scalar) {
        pa_opts.axes.push(Axis::Scalar);
    }
    let lowrank_ranks = {
        let mut rs = vec![2usize, 4, 8];
        if !rs.contains(&opts.lowrank_rank) {
            rs.push(opts.lowrank_rank);
            rs.sort_unstable();
        }
        rs
    };
    let mut out = Vec::with_capacity(cfg.n_patchable());
    for layer in 0..cfg.n_layers {
        let caches =
            build_layer_caches(finetuned, base, &tf, layer, calib_docs, opts.max_cache_rows);
        for kind in crate::model::ProjKind::ALL {
            let id = ModuleId { layer, kind };
            let w_base = base.module(id);
            let w_ft = finetuned.module(id);
            let measure = |ck: CodecKind, eopts: &CompressOptions, rank: Option<usize>| {
                let (m, rep) = codec_for(ck).encode(id, w_base, w_ft, &caches[&kind], eopts);
                let cand = &rep.codec_candidates[0];
                ShootoutRow {
                    kind: ck,
                    rank,
                    val_mse: cand.val_mse,
                    payload_bytes: cand.payload_bytes,
                    fused_rows_per_s: fused_rows_per_s(w_base, &m, 8),
                }
            };
            let mut rows = Vec::with_capacity(CodecKind::ALL.len() + lowrank_ranks.len() - 1);
            for &ck in CodecKind::ALL.iter() {
                if ck == CodecKind::LowRank {
                    for &rank in &lowrank_ranks {
                        let mut r_opts = pa_opts.clone();
                        r_opts.lowrank_rank = rank;
                        rows.push(measure(ck, &r_opts, Some(rank)));
                    }
                } else {
                    rows.push(measure(ck, &pa_opts, None));
                }
            }
            // Same incumbent rule as `encode_auto`, restricted to the rows
            // `auto` can actually publish (sweep rows at non-configured
            // ranks are informational only): per-axis wins ties.
            let eligible =
                |r: &ShootoutRow| r.rank.is_none() || r.rank == Some(opts.lowrank_rank);
            let mut selected = 0; // rows[0] is per-axis: always eligible
            for (i, r) in rows.iter().enumerate().skip(1) {
                if eligible(r) && r.val_mse < rows[selected].val_mse {
                    selected = i;
                }
            }
            out.push(ModuleShootout {
                id,
                selected: rows[selected].kind,
                selected_rank: rows[selected].rank,
                rows,
            });
        }
    }
    out
}

/// Render the shoot-out as an aligned text table (one line per module ×
/// codec; the selected codec is starred).
pub fn render_shootout(results: &[ModuleShootout]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<12} {:>9} {:>14} {:>12} {:>14} sel\n",
        "module", "codec", "val-mse", "bytes", "fused-rows/s"
    ));
    for ms in results {
        for r in &ms.rows {
            s.push_str(&format!(
                "{:<12} {:>9} {:>14.6e} {:>12} {:>14.0} {}\n",
                ms.id.to_string(),
                r.label(),
                r.val_mse,
                r.payload_bytes,
                r.fused_rows_per_s,
                if r.kind == ms.selected && r.rank == ms.selected_rank { "*" } else { "" }
            ));
        }
    }
    s
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = (f32::NEG_INFINITY, 0usize);
    for (i, &x) in xs.iter().enumerate() {
        if x > best.0 {
            best = (x, i);
        }
    }
    best.1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::synth::{synth_finetune, SynthDeltaSpec};

    #[test]
    fn self_fidelity_is_perfect() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let p = FlatParams::init(&cfg, 1);
        let tf = Transformer::new(&cfg);
        let docs = vec![vec![1u8, 2, 3, 4, 5, 6, 7, 8]];
        let f = fidelity(&tf, &p, &p, &docs);
        assert_eq!(f.logit_mse, 0.0);
        assert!(f.kl.abs() < 1e-9);
        assert_eq!(f.agreement, 1.0);
        assert_eq!(f.n_tokens, 8);
    }

    #[test]
    fn fidelity_degrades_with_perturbation_size() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let p = FlatParams::init(&cfg, 2);
        let tf = Transformer::new(&cfg);
        let small = synth_finetune(
            &p,
            &SynthDeltaSpec { magnitude: 0.005, anisotropy: 0.5, ..Default::default() },
        );
        let large = synth_finetune(
            &p,
            &SynthDeltaSpec { magnitude: 0.1, anisotropy: 0.5, ..Default::default() },
        );
        let docs = vec![(10..40u8).collect::<Vec<u8>>()];
        let fs = fidelity(&tf, &p, &small, &docs);
        let fl = fidelity(&tf, &p, &large, &docs);
        assert!(fs.logit_mse < fl.logit_mse);
        assert!(fs.kl < fl.kl);
        assert!(fs.agreement >= fl.agreement);
    }

    #[test]
    fn shootout_per_axis_never_loses_to_scalar_and_auto_never_loses_to_per_axis() {
        use crate::delta::compress::FitMode;
        let cfg = ModelConfig::preset("tiny").unwrap();
        let base = FlatParams::init(&cfg, 10);
        let ft = synth_finetune(
            &base,
            &SynthDeltaSpec { magnitude: 0.02, anisotropy: 1.2, axis_bias: 0.8, seed: 20 },
        );
        let docs: Vec<Vec<u8>> = (0..4)
            .map(|i| (0..30).map(|t| ((t * 7 + i * 13) % 250 + 1) as u8).collect())
            .collect();
        let opts = CompressOptions { fit: FitMode::ClosedForm, ..Default::default() };
        let results = codec_shootout(&base, &ft, &docs, &opts);
        assert_eq!(results.len(), cfg.n_patchable());
        for ms in &results {
            let by = |k: CodecKind| ms.rows.iter().find(|r| r.kind == k).unwrap();
            let pa = by(CodecKind::PerAxis);
            let sc = by(CodecKind::Scalar);
            let sel = ms.selected_row();
            assert!(
                pa.val_mse <= sc.val_mse,
                "{}: per-axis {} must not lose to scalar {}",
                ms.id,
                pa.val_mse,
                sc.val_mse
            );
            assert!(
                sel.val_mse <= pa.val_mse,
                "{}: selected {:?} ({}) worse than per-axis ({})",
                ms.id,
                ms.selected,
                sel.val_mse,
                pa.val_mse
            );
            for r in &ms.rows {
                assert!(r.fused_rows_per_s > 0.0);
                assert!(r.payload_bytes > 0);
            }
            // The lowrank sweep emits one row per rank in {2, 4, 8} (the
            // default configured rank is 4) and bytes grow with rank.
            let ranks: Vec<usize> = ms
                .rows
                .iter()
                .filter(|r| r.kind == CodecKind::LowRank)
                .map(|r| r.rank.unwrap())
                .collect();
            assert_eq!(ranks, vec![2, 4, 8], "{}: lowrank sweep ranks", ms.id);
            let lr = |rank: usize| ms.rows.iter().find(|r| r.rank == Some(rank)).unwrap();
            assert!(lr(2).payload_bytes < lr(4).payload_bytes);
            assert!(lr(4).payload_bytes < lr(8).payload_bytes);
            // Only the configured rank is selectable.
            if ms.selected == CodecKind::LowRank {
                assert_eq!(ms.selected_rank, Some(opts.lowrank_rank));
            } else {
                assert_eq!(ms.selected_rank, None);
            }
        }
        let rendered = render_shootout(&results);
        assert!(rendered.contains("per-axis") && rendered.contains('*'));
        assert!(rendered.contains("lowrank@2") && rendered.contains("lowrank@8"));
    }

    #[test]
    fn kl_is_nonnegative() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let a = FlatParams::init(&cfg, 3);
        let b = FlatParams::init(&cfg, 4);
        let tf = Transformer::new(&cfg);
        let docs = vec![(0..30u8).collect::<Vec<u8>>()];
        let f = fidelity(&tf, &a, &b, &docs);
        assert!(f.kl >= -1e-9, "kl={}", f.kl);
    }
}
