//! Teacher-fidelity metrics: how closely a compressed student reproduces
//! the fine-tuned teacher's *behaviour* (the paper's "preserve the function
//! the network computes" objective, §2 "Prior evidence against weight
//! reconstruction").

use crate::model::{FlatParams, Transformer};
use crate::tensor::ops::log_softmax_into;

/// Fidelity of `student` against `teacher` measured on a set of documents.
#[derive(Clone, Debug, Default)]
pub struct Fidelity {
    /// Mean squared error between logits.
    pub logit_mse: f64,
    /// Mean KL(teacher ‖ student) per token (nats).
    pub kl: f64,
    /// Fraction of positions where the argmax token agrees.
    pub agreement: f64,
    pub n_tokens: usize,
}

pub fn fidelity(
    tf: &Transformer,
    teacher: &FlatParams,
    student: &FlatParams,
    docs: &[Vec<u8>],
) -> Fidelity {
    let vocab = tf.cfg.vocab;
    let mut mse = 0f64;
    let mut kl = 0f64;
    let mut agree = 0usize;
    let mut n = 0usize;
    let mut lt = vec![0f32; vocab];
    let mut ls = vec![0f32; vocab];
    for doc in docs {
        if doc.len() < 2 {
            continue;
        }
        let t_logits = tf.forward_one(teacher, doc);
        let s_logits = tf.forward_one(student, doc);
        for pos in 0..doc.len() {
            let (tr, sr) = (t_logits.row(pos), s_logits.row(pos));
            let mut row_mse = 0f64;
            for (a, b) in tr.iter().zip(sr) {
                let d = (a - b) as f64;
                row_mse += d * d;
            }
            mse += row_mse / vocab as f64;
            log_softmax_into(tr, &mut lt);
            log_softmax_into(sr, &mut ls);
            let mut row_kl = 0f64;
            for (a, b) in lt.iter().zip(&ls) {
                row_kl += (a.exp() as f64) * ((a - b) as f64);
            }
            kl += row_kl;
            let t_arg = argmax(tr);
            let s_arg = argmax(sr);
            if t_arg == s_arg {
                agree += 1;
            }
            n += 1;
        }
    }
    if n == 0 {
        return Fidelity::default();
    }
    Fidelity {
        logit_mse: mse / n as f64,
        kl: kl / n as f64,
        agreement: agree as f64 / n as f64,
        n_tokens: n,
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = (f32::NEG_INFINITY, 0usize);
    for (i, &x) in xs.iter().enumerate() {
        if x > best.0 {
            best = (x, i);
        }
    }
    best.1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::synth::{synth_finetune, SynthDeltaSpec};

    #[test]
    fn self_fidelity_is_perfect() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let p = FlatParams::init(&cfg, 1);
        let tf = Transformer::new(&cfg);
        let docs = vec![vec![1u8, 2, 3, 4, 5, 6, 7, 8]];
        let f = fidelity(&tf, &p, &p, &docs);
        assert_eq!(f.logit_mse, 0.0);
        assert!(f.kl.abs() < 1e-9);
        assert_eq!(f.agreement, 1.0);
        assert_eq!(f.n_tokens, 8);
    }

    #[test]
    fn fidelity_degrades_with_perturbation_size() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let p = FlatParams::init(&cfg, 2);
        let tf = Transformer::new(&cfg);
        let small = synth_finetune(
            &p,
            &SynthDeltaSpec { magnitude: 0.005, anisotropy: 0.5, ..Default::default() },
        );
        let large = synth_finetune(
            &p,
            &SynthDeltaSpec { magnitude: 0.1, anisotropy: 0.5, ..Default::default() },
        );
        let docs = vec![(10..40u8).collect::<Vec<u8>>()];
        let fs = fidelity(&tf, &p, &small, &docs);
        let fl = fidelity(&tf, &p, &large, &docs);
        assert!(fs.logit_mse < fl.logit_mse);
        assert!(fs.kl < fl.kl);
        assert!(fs.agreement >= fl.agreement);
    }

    #[test]
    fn kl_is_nonnegative() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let a = FlatParams::init(&cfg, 3);
        let b = FlatParams::init(&cfg, 4);
        let tf = Transformer::new(&cfg);
        let docs = vec![(0..30u8).collect::<Vec<u8>>()];
        let f = fidelity(&tf, &a, &b, &docs);
        assert!(f.kl >= -1e-9, "kl={}", f.kl);
    }
}
