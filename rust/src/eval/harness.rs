//! Zero-shot multiple-choice accuracy (the paper's Table 1 metric).
//!
//! Scoring follows lm-eval-harness: for each item, every choice text is
//! appended to the prompt and scored by the sum of completion-token
//! log-likelihoods; the argmax choice is the prediction. Items are scored
//! in parallel across a thread pool (the native engine) — the serving path
//! in `coordinator` runs the same computation through batched AOT forwards.

use crate::data::corpus::encode;
use crate::data::tasks::{McItem, TaskFamily};
use crate::exec::Weights;
use crate::model::Transformer;
use crate::util::par;
use std::sync::Mutex;

/// Result of one task family.
#[derive(Clone, Debug)]
pub struct FamilyResult {
    pub family: TaskFamily,
    pub n_items: usize,
    pub n_correct: usize,
}

impl FamilyResult {
    pub fn accuracy(&self) -> f64 {
        if self.n_items == 0 {
            0.0
        } else {
            self.n_correct as f64 / self.n_items as f64
        }
    }
}

/// Full-suite result (all five families + average, a Table-1 row).
#[derive(Clone, Debug)]
pub struct SuiteResult {
    pub label: String,
    pub families: Vec<FamilyResult>,
}

impl SuiteResult {
    pub fn average(&self) -> f64 {
        if self.families.is_empty() {
            return 0.0;
        }
        self.families.iter().map(|f| f.accuracy()).sum::<f64>() / self.families.len() as f64
    }

    /// Accuracy for one family (percent).
    pub fn pct(&self, family: TaskFamily) -> f64 {
        self.families
            .iter()
            .find(|f| f.family == family)
            .map(|f| f.accuracy() * 100.0)
            .unwrap_or(f64::NAN)
    }
}

/// Score one MC item: returns the predicted choice index. Generic over the
/// weight source, so the same harness evaluates dense parameters and packed
/// variants (the dense-vs-fused A/B switch is just the `weights` argument).
pub fn predict<W: Weights>(tf: &Transformer, weights: &W, item: &McItem) -> usize {
    let mut best = (f64::NEG_INFINITY, 0usize);
    for (ci, choice) in item.choices.iter().enumerate() {
        let full = encode(&format!("{}{}", item.prompt, choice));
        let full = clamp_tokens(full, tf.cfg.max_seq);
        // The choice is the tail of the sequence; score exactly its tokens
        // (robust under prompt clamping). Length-normalized as lm-eval does.
        let choice_len = encode(choice).len().min(full.len() - 1).max(1);
        let start = full.len() - choice_len;
        let score = tf.score_span(weights, &full, start..full.len());
        let s = score / choice_len as f64;
        if s > best.0 {
            best = (s, ci);
        }
    }
    best.1
}

/// Keep the *tail* of an over-long sequence (the answer span must survive).
fn clamp_tokens(tokens: Vec<u8>, max: usize) -> Vec<u8> {
    if tokens.len() <= max {
        tokens
    } else {
        tokens[tokens.len() - max..].to_vec()
    }
}

/// Accuracy of `weights` on a set of items (parallel over items).
pub fn mc_accuracy<W: Weights>(tf: &Transformer, weights: &W, items: &[McItem]) -> FamilyResult {
    let family = items.first().map(|i| i.family).unwrap_or(TaskFamily::AttrEasy);
    let correct = Mutex::new(0usize);
    par::parallel_items(items.len(), 16, |i| {
        if predict(tf, weights, &items[i]) == items[i].correct {
            *correct.lock().unwrap() += 1;
        }
    });
    FamilyResult { family, n_items: items.len(), n_correct: correct.into_inner().unwrap() }
}

/// Evaluate all five families, `n_per_family` items each.
pub fn evaluate_suite<W: Weights>(
    label: &str,
    tf: &Transformer,
    weights: &W,
    world: &crate::data::World,
    n_per_family: usize,
    seed: u64,
) -> SuiteResult {
    let families = TaskFamily::ALL
        .iter()
        .map(|&fam| {
            let items = crate::data::tasks::eval_items(world, fam, n_per_family, seed);
            mc_accuracy(tf, weights, &items)
        })
        .collect();
    SuiteResult { label: label.to_string(), families }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::eval_items;
    use crate::data::World;
    use crate::model::config::ModelConfig;
    use crate::model::FlatParams;

    #[test]
    fn random_model_is_near_chance() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let params = FlatParams::init(&cfg, 1);
        let tf = Transformer::new(&cfg);
        let world = World::generate(3, 24);
        let items = eval_items(&world, TaskFamily::AttrEasy, 40, 5);
        let res = mc_accuracy(&tf, &params, &items);
        // 4-way chance = 25%; a random-init byte LM should be within noise.
        let acc = res.accuracy();
        assert!((0.0..=0.6).contains(&acc), "acc={acc}");
        assert_eq!(res.n_items, 40);
    }

    #[test]
    fn predict_is_deterministic() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let params = FlatParams::init(&cfg, 2);
        let tf = Transformer::new(&cfg);
        let world = World::generate(4, 24);
        let items = eval_items(&world, TaskFamily::Physical, 10, 6);
        for it in &items {
            assert_eq!(predict(&tf, &params, it), predict(&tf, &params, it));
        }
    }

    #[test]
    fn suite_has_five_families() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let params = FlatParams::init(&cfg, 3);
        let tf = Transformer::new(&cfg);
        let world = World::generate(5, 24);
        let res = evaluate_suite("test", &tf, &params, &world, 5, 7);
        assert_eq!(res.families.len(), 5);
        let avg = res.average();
        assert!((0.0..=1.0).contains(&avg));
    }

    #[test]
    fn clamp_keeps_tail() {
        let t: Vec<u8> = (0..100).collect();
        let c = clamp_tokens(t, 10);
        assert_eq!(c.len(), 10);
        assert_eq!(c[9], 99);
    }
}
