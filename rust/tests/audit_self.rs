//! Tier-1 gate: the repo audits itself to **zero findings** at HEAD.
//!
//! Every pass in `pawd::audit` runs over the live tree. A failure here is
//! either a real defect (fix the code) or a deliberate exception (annotate
//! the line with `// audit:allow(<pass>)` or update the golden unsafe
//! inventory) — never something to silence by weakening the pass.

use pawd::audit::{run_repo_audit, AuditReport, Finding};
use pawd::util::json::Json;
use std::path::Path;

fn repo_root() -> &'static Path {
    // CARGO_MANIFEST_DIR is <repo>/rust; the audit runs from the repo root
    // so README.md and the golden files are in scope.
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().expect("rust/ has a parent")
}

#[test]
fn repo_audit_is_clean() {
    let report = run_repo_audit(repo_root()).expect("audit completes");
    assert!(
        report.files_scanned > 80,
        "suspiciously few files audited ({}) — tree layout changed?",
        report.files_scanned
    );
    assert!(
        report.findings.is_empty(),
        "audit found {} issue(s) at HEAD:\n{}",
        report.findings.len(),
        report
            .findings
            .iter()
            .map(|f| f.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
}

#[test]
fn report_round_trips_through_util_json() {
    let report = AuditReport {
        files_scanned: 3,
        findings: vec![
            Finding::new("A001", "bracket-balance", "rust/src/x.rs", 7, "unclosed '{'".into()),
            Finding::new(
                "A101",
                "counter-drift",
                "README.md",
                1,
                "counter 'demo' missing from the README counter table".into(),
            ),
        ],
    };
    let text = report.to_json().to_string();
    let parsed = Json::parse(&text).expect("audit JSON parses back");
    let back = AuditReport::from_json(&parsed).expect("report decodes");
    assert_eq!(back, report);
}
