//! Hostile-input coverage for the network plane: the parser and the live
//! server must answer malformed, truncated, oversized, and slow-loris
//! traffic with typed errors and clean drops — never a panic, never a hang.

mod common;

use common::{fresh_dir, with_timeout};
use pawd::coordinator::VariantRegistry;
use pawd::net::http::{HttpConn, HttpError, HttpLimits};
use pawd::net::{FrontConfig, HttpApiClient, HttpFrontend};
use pawd::util::rng::Rng;
use std::io::{Cursor, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

const TEMPLATES: &[&[u8]] = &[
    b"GET /v1/healthz HTTP/1.1\r\nHost: t\r\n\r\n",
    b"GET /v1/sync/manifest?known_seq=7&timeout_ms=100 HTTP/1.1\r\nHost: t\r\n\r\n",
    b"GET /v1/sync/file/ft%401.pawd HTTP/1.1\r\nRange: bytes=1024-\r\n\r\n",
    b"POST /v1/query HTTP/1.1\r\nContent-Length: 24\r\n\r\n{\"variant\":\"ft\",\"op\":\"x\"}",
    b"POST /v1/admin/publish HTTP/1.0\r\nConnection: keep-alive\r\nContent-Length: 2\r\n\r\n{}",
];

fn parse(raw: &[u8]) -> Result<Option<pawd::net::http::HttpRequest>, HttpError> {
    HttpConn::new(Cursor::new(raw.to_vec())).read_request(&HttpLimits::default())
}

#[test]
fn parser_handles_every_truncation_point() {
    for template in TEMPLATES {
        for cut in 0..template.len() {
            // Every prefix must come back as a typed result — clean close,
            // truncation, or a malformed/unsupported rejection.
            match parse(&template[..cut]) {
                Ok(None) | Ok(Some(_)) => {}
                Err(e) => {
                    let _ = e.status();
                    let _ = e.to_string();
                }
            }
        }
        assert!(parse(template).unwrap().is_some(), "intact template must parse");
    }
}

#[test]
fn parser_survives_random_mutations() {
    let mut rng = Rng::new(0xB0A7);
    for iter in 0..2000 {
        let mut bytes = TEMPLATES[iter % TEMPLATES.len()].to_vec();
        for _ in 0..rng.range(1, 9) {
            let pos = rng.below(bytes.len());
            bytes[pos] = rng.next_u32() as u8;
        }
        // Typed error or parse — never a panic. Oversized declared bodies
        // are capped, so even a mutated Content-Length can't balloon.
        match parse(&bytes) {
            Ok(_) => {}
            Err(e) => {
                let _ = e.status();
            }
        }
    }
}

#[test]
fn live_server_survives_hostile_connections() {
    with_timeout("hostile_server", 120, || {
        let dir = fresh_dir("pawd_itest_net_hostile");
        let registry = Arc::new(VariantRegistry::open(&dir).unwrap());
        // Tight deadlines so the slow-loris probe resolves in test time.
        let cfg = FrontConfig {
            limits: HttpLimits {
                head_deadline: Duration::from_millis(500),
                body_deadline: Duration::from_millis(500),
                ..HttpLimits::default()
            },
            ..FrontConfig::default()
        };
        let frontend = HttpFrontend::start("127.0.0.1:0", None, registry, cfg).unwrap();
        let addr = frontend.addr();
        let exchange = |req: &[u8]| -> String {
            let mut s = TcpStream::connect(addr).unwrap();
            s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
            s.write_all(req).unwrap();
            s.shutdown(std::net::Shutdown::Write).unwrap();
            let mut buf = Vec::new();
            let _ = s.read_to_end(&mut buf);
            String::from_utf8_lossy(&buf).into_owned()
        };

        // Non-HTTP garbage: the server drops (with or without a 400 line).
        let resp = exchange(b"\x00\x01\x02garbage\xff\xfe\r\n\r\n");
        assert!(resp.is_empty() || resp.starts_with("HTTP/1.1 4"), "got: {resp}");

        // Oversized head → 431.
        let mut big = b"GET / HTTP/1.1\r\nX-Filler: ".to_vec();
        big.resize(big.len() + 20_000, b'a');
        big.extend_from_slice(b"\r\n\r\n");
        assert!(exchange(&big).starts_with("HTTP/1.1 431"), "oversized head must 431");

        // Huge declared body → 413 without reading it.
        let resp =
            exchange(b"POST /v1/query HTTP/1.1\r\nContent-Length: 99999999999\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 413"), "got: {resp}");

        // Chunked transfer → 501 (the plane refuses, typed).
        let resp =
            exchange(b"POST /v1/query HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n0\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 501"), "got: {resp}");

        // Slow loris: trickle a never-ending head and stop. The deadline
        // must cut the connection instead of pinning a thread forever.
        {
            let mut s = TcpStream::connect(addr).unwrap();
            s.set_read_timeout(Some(Duration::from_secs(8))).unwrap();
            s.write_all(b"GET /v1/healthz HTTP/1.1\r\nX-Drip: a").unwrap();
            std::thread::sleep(Duration::from_millis(150));
            s.write_all(b"b").unwrap();
            // No terminator, no more bytes: the server's 500ms head
            // deadline fires and the socket closes (408 line optional).
            let mut buf = Vec::new();
            let n = s.read_to_end(&mut buf).unwrap_or(0);
            let text = String::from_utf8_lossy(&buf[..n.min(buf.len())]).into_owned();
            assert!(
                text.is_empty() || text.starts_with("HTTP/1.1 408"),
                "slow-loris must end in a drop or a 408, got: {text}"
            );
        }

        // Connect-and-vanish costs nothing.
        drop(TcpStream::connect(addr).unwrap());

        // After all of that, the server still answers politely.
        HttpApiClient::new(&frontend.url()).unwrap().health().unwrap();
    })
}

#[test]
fn file_route_rejects_traversal_and_misses_cleanly() {
    with_timeout("hostile_file_route", 60, || {
        let dir = fresh_dir("pawd_itest_net_traversal");
        let registry = Arc::new(VariantRegistry::open(&dir).unwrap());
        let frontend =
            HttpFrontend::start("127.0.0.1:0", None, registry, FrontConfig::default()).unwrap();
        let addr = frontend.addr();
        let exchange = |req: &[u8]| -> String {
            let mut s = TcpStream::connect(addr).unwrap();
            s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
            s.write_all(req).unwrap();
            s.shutdown(std::net::Shutdown::Write).unwrap();
            let mut buf = Vec::new();
            let _ = s.read_to_end(&mut buf);
            String::from_utf8_lossy(&buf).into_owned()
        };

        // Encoded traversal dies at the parser (400), never reaching fs.
        let resp = exchange(b"GET /v1/sync/file/..%2Fregistry.json HTTP/1.1\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 400"), "got: {resp}");
        // Dotfiles are rejected by the same gate the replicator uses.
        let resp = exchange(b"GET /v1/sync/file/.hidden HTTP/1.1\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 400"), "got: {resp}");
        // A clean miss is a 404, not an error or a path probe.
        let resp = exchange(b"GET /v1/sync/file/nope.pawd HTTP/1.1\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 404"), "got: {resp}");
        // Bad long-poll parameters are 400s.
        let resp = exchange(b"GET /v1/sync/manifest?known_seq=banana HTTP/1.1\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 400"), "got: {resp}");
    })
}
