//! Multi-node replication integration: follower cold-sync (consolidated
//! fetch), warm-sync (patch-only fetch with the parent resident), crash
//! mid-sync (a partial file is never committed to the manifest), leader
//! rollback/retire convergence, and the server admin plane's `PullFrom`
//! warming synced versions into the cache.
//!
//! Wire accounting is asserted through each pass's [`SyncReport`] (per-call,
//! race-free); the `replication_sync` bench asserts the same structure
//! through the global `exec::counters` wire gauges in a single process.

mod common;

use common::{fresh_dir, with_timeout};
use pawd::coordinator::{
    AdminOp, Engine, FsTransport, Replicator, Server, ServerConfig, SyncTransport,
    VariantRegistry, VariantStore,
};
use pawd::net::{FrontConfig, HttpFrontend, HttpTransport};
use pawd::delta::types::{Axis, DeltaModel};
use pawd::exec::ExecMode;
use pawd::model::config::ModelConfig;
use pawd::model::{FlatParams, Transformer};
use std::path::Path;
use std::sync::Arc;

/// Row-axis seeded delta (deterministic single-axis layout).
fn seeded_full(base: &FlatParams, variant: &str, seed: u64) -> DeltaModel {
    common::seeded_full(base, variant, seed, &[Axis::Row])
}

/// `model` with module `k` replaced by freshly seeded content.
fn perturb_one(model: &DeltaModel, base: &FlatParams, k: usize, seed: u64) -> DeltaModel {
    let mut out = model.clone();
    let fresh = seeded_full(base, &model.variant, seed);
    out.modules[k] = fresh.modules[k].clone();
    out
}

/// Bitwise logits of `name` (active version) served fused from `dir`.
fn logits_of(base: &Arc<FlatParams>, dir: &Path, name: &str, tokens: &[u8]) -> Vec<u32> {
    let store = VariantStore::new(base.clone(), dir).with_mode(ExecMode::Fused);
    let tf = Transformer::new(base.cfg());
    let loaded = store.load(name).unwrap();
    tf.forward_one(&loaded.weights, tokens).data.iter().map(|x| x.to_bits()).collect()
}

fn file_size(path: &Path) -> u64 {
    std::fs::metadata(path).map(|m| m.len()).unwrap_or(0)
}

#[test]
fn cold_sync_replicates_chains_and_logits_match_bitwise() {
    let leader_dir = fresh_dir("pawd_itest_repl_cold_leader");
    let follower_dir = fresh_dir("pawd_itest_repl_cold_follower");
    let cfg = ModelConfig::preset("tiny").unwrap();
    let base = Arc::new(FlatParams::init(&cfg, 11));
    let leader = VariantRegistry::open(&leader_dir).unwrap();
    // "ft": full v1 + patch v2 (a live chain); "other": a lone full v1.
    let v1 = seeded_full(&base, "ft", 1);
    leader.publish_incremental("ft", v1.clone(), None).unwrap();
    let v2 = perturb_one(&v1, &base, 2, 99);
    let out2 = leader.publish_incremental("ft", v2, None).unwrap();
    assert!(out2.patch, "single-module change must ship as a patch");
    leader.publish("other", seeded_full(&base, "other", 7)).unwrap();

    let follower = Arc::new(VariantRegistry::open(&follower_dir).unwrap());
    let repl = Replicator::new(follower.clone(), Box::new(FsTransport::new(&leader_dir)));
    let report = repl.sync_once(None).unwrap();
    assert!(!report.up_to_date);
    assert_eq!(report.variants_synced, 2);
    assert_eq!(report.versions_installed, 3);
    assert_eq!(report.files_fetched, 3, "cold sync fetches the whole chain");
    assert_eq!(report.patch_files_fetched, 1);
    assert!(report.artifact_bytes > 0 && report.manifest_bytes > 0);
    assert_eq!(report.leader_seq, leader.manifest_seq());

    // The follower resolves the same state the leader serves.
    let r = follower.resolve("ft").unwrap();
    assert_eq!((r.version, r.patch, r.parent), (2, true, Some(1)));
    assert_eq!(follower.resolve("other").unwrap().version, 1);
    // Post-sync eval logits are bitwise-equal for every replicated variant.
    let tokens: Vec<u8> = (0..12u8).map(|t| t.wrapping_mul(19) % 200 + 10).collect();
    for name in ["ft", "ft@1", "ft@2", "other"] {
        assert_eq!(
            logits_of(&base, &leader_dir, name, &tokens),
            logits_of(&base, &follower_dir, name, &tokens),
            "leader and follower must serve bitwise-identical logits for '{name}'"
        );
    }
    // A second pass is a pure no-op (manifest_seq fast path).
    let again = repl.sync_once(None).unwrap();
    assert!(again.up_to_date);
    assert_eq!(again.files_fetched, 0);
    assert_eq!(again.artifact_bytes, 0);
}

#[test]
fn warm_sync_fetches_only_the_patch() {
    let leader_dir = fresh_dir("pawd_itest_repl_warm_leader");
    let follower_dir = fresh_dir("pawd_itest_repl_warm_follower");
    let cfg = ModelConfig::preset("tiny").unwrap();
    let base = Arc::new(FlatParams::init(&cfg, 13));
    let leader = VariantRegistry::open(&leader_dir).unwrap();
    let v1 = seeded_full(&base, "ft", 21);
    let full = leader.publish_incremental("ft", v1.clone(), None).unwrap();

    let follower = Arc::new(VariantRegistry::open(&follower_dir).unwrap());
    let repl = Replicator::new(follower.clone(), Box::new(FsTransport::new(&leader_dir)));
    repl.sync_once(None).unwrap();
    assert_eq!(follower.resolve("ft").unwrap().version, 1);

    // Leader ships a patch; the follower holds the chain parent, so the
    // second sync moves ONLY the patch bytes.
    let v2 = perturb_one(&v1, &base, 1, 555);
    let out = leader.publish_incremental("ft", v2, None).unwrap();
    assert!(out.patch);
    let report = repl.sync_once(None).unwrap();
    assert_eq!(report.files_fetched, 1, "warm sync must fetch the patch only");
    assert_eq!(report.patch_files_fetched, 1);
    assert_eq!(
        report.artifact_bytes, out.bytes,
        "wire bytes must equal the patch artifact exactly"
    );
    assert!(
        report.artifact_bytes < full.bytes / 2,
        "patch transfer ({}) must be a fraction of the consolidated artifact ({})",
        report.artifact_bytes,
        full.bytes
    );
    let r = follower.resolve("ft").unwrap();
    assert_eq!((r.version, r.patch), (2, true));
    let tokens: Vec<u8> = (0..10u8).map(|t| t.wrapping_mul(31) % 200 + 10).collect();
    assert_eq!(
        logits_of(&base, &leader_dir, "ft", &tokens),
        logits_of(&base, &follower_dir, "ft", &tokens),
    );

    // Leader consolidates v2 in place: the follower swaps to the full file
    // and drops its superseded patch copy.
    let patch_file = follower_dir.join(&follower.list()[0].versions[1].file);
    leader.consolidate("ft", Some(2)).unwrap();
    let report = repl.sync_once(None).unwrap();
    assert_eq!(report.files_fetched, 1);
    assert_eq!(report.patch_files_fetched, 0);
    let r = follower.resolve("ft").unwrap();
    assert_eq!((r.version, r.patch), (2, false));
    assert!(!patch_file.exists(), "superseded patch file must be unlinked");
    assert_eq!(
        logits_of(&base, &leader_dir, "ft", &tokens),
        logits_of(&base, &follower_dir, "ft", &tokens),
    );
}

/// Transport that truncates artifact payloads mid-file (a dropped
/// connection) or flips a bit (corruption in flight).
struct FaultyTransport {
    inner: FsTransport,
    mode: FaultMode,
}

enum FaultMode {
    TruncateArtifacts,
    CorruptArtifacts,
}

impl SyncTransport for FaultyTransport {
    fn describe(&self) -> String {
        format!("faulty({})", self.inner.describe())
    }

    fn fetch_manifest(&self) -> anyhow::Result<Vec<u8>> {
        self.inner.fetch_manifest()
    }

    fn fetch_file(&self, file: &str, dest: &Path) -> anyhow::Result<u64> {
        let n = self.inner.fetch_file(file, dest)?;
        let mut bytes = std::fs::read(dest)?;
        match self.mode {
            FaultMode::TruncateArtifacts => {
                bytes.truncate(bytes.len() / 2);
                std::fs::write(dest, &bytes)?;
                anyhow::bail!("connection reset mid-transfer of '{file}'");
            }
            FaultMode::CorruptArtifacts => {
                let mid = bytes.len() / 2;
                bytes[mid] ^= 0x40;
                std::fs::write(dest, &bytes)?;
                Ok(n)
            }
        }
    }
}

#[test]
fn crash_mid_sync_never_commits_a_partial_file() {
    let leader_dir = fresh_dir("pawd_itest_repl_crash_leader");
    let follower_dir = fresh_dir("pawd_itest_repl_crash_follower");
    let cfg = ModelConfig::preset("tiny").unwrap();
    let base = Arc::new(FlatParams::init(&cfg, 17));
    let leader = VariantRegistry::open(&leader_dir).unwrap();
    leader.publish("ft", seeded_full(&base, "ft", 31)).unwrap();

    for mode in [FaultMode::TruncateArtifacts, FaultMode::CorruptArtifacts] {
        let follower = Arc::new(VariantRegistry::open(&follower_dir).unwrap());
        let repl = Replicator::new(
            follower.clone(),
            Box::new(FaultyTransport { inner: FsTransport::new(&leader_dir), mode }),
        );
        let err = repl.sync_once(None).unwrap_err().to_string();
        assert!(!err.is_empty());
        // Nothing committed: the variant does not resolve, no artifact file
        // and no temp debris were left in the follower directory.
        assert!(follower.resolve("ft").is_err(), "partial sync must not commit");
        let leftovers: Vec<String> = std::fs::read_dir(&follower_dir)
            .map(|rd| {
                rd.filter_map(|e| e.ok())
                    .map(|e| e.file_name().to_string_lossy().into_owned())
                    .filter(|n| n != "registry.json")
                    .collect()
            })
            .unwrap_or_default();
        assert!(leftovers.is_empty(), "crashed sync left {leftovers:?}");
        // A restart sees the same clean state (the manifest, if one was
        // written at all, records no versions).
        let reopened = VariantRegistry::open(&follower_dir).unwrap();
        assert!(reopened.resolve("ft").is_err());
    }

    // The retry over a healthy transport succeeds from the same state.
    let follower = Arc::new(VariantRegistry::open(&follower_dir).unwrap());
    let repl = Replicator::new(follower.clone(), Box::new(FsTransport::new(&leader_dir)));
    let report = repl.sync_once(None).unwrap();
    assert_eq!(report.files_fetched, 1);
    assert_eq!(follower.resolve("ft").unwrap().version, 1);
}

#[test]
fn leader_rollback_and_retire_converge_without_refetching() {
    let leader_dir = fresh_dir("pawd_itest_repl_rb_leader");
    let follower_dir = fresh_dir("pawd_itest_repl_rb_follower");
    let cfg = ModelConfig::preset("tiny").unwrap();
    let base = Arc::new(FlatParams::init(&cfg, 23));
    let leader = VariantRegistry::open(&leader_dir).unwrap();
    leader.publish("ft", seeded_full(&base, "ft", 41)).unwrap();
    leader.publish("ft", seeded_full(&base, "ft", 42)).unwrap();

    let follower = Arc::new(VariantRegistry::open(&follower_dir).unwrap());
    let repl = Replicator::new(follower.clone(), Box::new(FsTransport::new(&leader_dir)));
    repl.sync_once(None).unwrap();
    assert_eq!(follower.resolve("ft").unwrap().version, 2);

    // Rollback on the leader: the follower converges by moving its alias —
    // zero artifact bytes over the wire (both versions are already held).
    leader.rollback("ft", None).unwrap();
    let report = repl.sync_once(None).unwrap();
    assert!(!report.up_to_date);
    assert_eq!(report.files_fetched, 0, "rollback must not refetch artifacts");
    assert_eq!(report.artifact_bytes, 0);
    assert_eq!(follower.resolve("ft").unwrap().version, 1);
    let tokens: Vec<u8> = (0..8u8).map(|t| t.wrapping_mul(37) % 200 + 10).collect();
    assert_eq!(
        logits_of(&base, &leader_dir, "ft", &tokens),
        logits_of(&base, &follower_dir, "ft", &tokens),
    );

    // Retire on the leader: mirrored; the retired version stops resolving
    // on the follower too, again with no transfer.
    leader.retire("ft", 2).unwrap();
    let report = repl.sync_once(None).unwrap();
    assert_eq!(report.artifact_bytes, 0);
    assert!(follower.resolve("ft@2").is_err(), "retired versions must not resolve");
    assert_eq!(follower.resolve("ft").unwrap().version, 1);

    // Leader-side gc tombstones replicate as records only; the follower
    // keeps its local file until a local gc unlinks it.
    leader.gc(Some("ft")).unwrap();
    let follower_v2_file = follower_dir.join(&follower.list()[0].versions[1].file);
    assert!(follower_v2_file.exists());
    let report = repl.sync_once(None).unwrap();
    assert_eq!(report.artifact_bytes, 0);
    assert!(follower_v2_file.exists(), "a leader gc must not delete follower files");
    let local_gc = follower.gc(Some("ft")).unwrap();
    assert_eq!(local_gc.files_removed, 1);
    assert!(!follower_v2_file.exists());
}

#[test]
fn server_admin_pull_from_syncs_and_warms_the_cache() {
    let leader_dir = fresh_dir("pawd_itest_repl_srv_leader");
    let follower_dir = fresh_dir("pawd_itest_repl_srv_follower");
    let cfg = ModelConfig::preset("tiny").unwrap();
    let base = Arc::new(FlatParams::init(&cfg, 29));
    let leader = VariantRegistry::open(&leader_dir).unwrap();
    let v1 = seeded_full(&base, "ft", 61);
    leader.publish_incremental("ft", v1.clone(), None).unwrap();

    let store = VariantStore::new(base.clone(), &follower_dir).with_mode(ExecMode::Fused);
    let server = Server::start(store, Engine::Native, ServerConfig::default());
    let client = server.client();
    let (seq0, variants0, _) = client.sync_status().unwrap();
    assert_eq!((seq0, variants0), (0, 0), "fresh follower starts empty");

    let report = client.pull_from(&leader_dir).unwrap();
    assert_eq!(report.files_fetched, 1);
    // PullFrom warms on arrival: the first data request is a cache hit.
    let resp = client.score("ft", "Q: probe? A: ", &["ok".into(), "bad".into()]);
    assert!(resp.result.is_ok(), "{:?}", resp.result);
    assert_eq!(resp.version, Some(1));
    assert!(resp.timing.cold_start.is_none(), "synced variant must be warm");
    let (seq1, variants1, versions1) = client.sync_status().unwrap();
    assert!(seq1 > 0);
    assert_eq!((variants1, versions1), (1, 1));

    // A patch publish on the leader replicates warm: only the patch moves,
    // and the follower keeps serving through the flip.
    let v2 = perturb_one(&v1, &base, 0, 777);
    let out = leader.publish_incremental("ft", v2, None).unwrap();
    assert!(out.patch);
    let report = client.pull_from(&leader_dir).unwrap();
    assert_eq!((report.files_fetched, report.patch_files_fetched), (1, 1));
    assert_eq!(report.artifact_bytes, out.bytes);
    let resp = client.score("ft", "Q: probe? A: ", &["ok".into(), "bad".into()]);
    assert!(resp.result.is_ok());
    assert_eq!(resp.version, Some(2), "follower serves the replicated version");
    assert!(resp.timing.cold_start.is_none(), "warm-on-arrival composed the patch");

    // Misdirected PullFrom at a bogus dir fails cleanly, server stays up.
    let err = client
        .admin(AdminOp::PullFrom { dir: follower_dir.join("nonexistent") })
        .unwrap_err();
    assert!(!err.is_empty());
    assert!(client.score("ft", "Q: again? A: ", &["ok".into(), "bad".into()]).result.is_ok());
    server.shutdown();
}

#[test]
fn mixed_codec_artifact_round_trips_fs_and_http_with_bitwise_logits() {
    with_timeout("mixed_codec_round_trip", 120, || {
        let leader_dir = fresh_dir("pawd_itest_repl_mixed_leader");
        let fs_dir = fresh_dir("pawd_itest_repl_mixed_fs");
        let http_dir = fresh_dir("pawd_itest_repl_mixed_http");
        let cfg = ModelConfig::preset("tiny").unwrap();
        let base = Arc::new(FlatParams::init(&cfg, 43));
        let leader = Arc::new(VariantRegistry::open(&leader_dir).unwrap());
        let tokens: Vec<u8> = (0..12u8).map(|t| t.wrapping_mul(23) % 200 + 10).collect();

        // Full mixed-codec publish (modules cycle per-axis/scalar/lowrank),
        // then an incremental patch touching one low-rank module (same
        // kind, new payload) — the diff must ship exactly that module.
        let v1 = common::seeded_full_mixed(&base, "mx", 5);
        leader.publish_incremental("mx", v1.clone(), None).unwrap();
        let mut v2 = v1.clone();
        let fresh = common::seeded_full_mixed(&base, "mx", 6);
        v2.modules[2] = fresh.modules[2].clone();
        assert!(v2.modules[2].lowrank().is_some(), "index 2 cycles to the lowrank codec");
        let out = leader.publish_incremental("mx", v2, None).unwrap();
        assert!(out.patch, "single-module change must ship as a patch");

        // FS follower.
        let fs_follower = Arc::new(VariantRegistry::open(&fs_dir).unwrap());
        let fs_repl = Replicator::new(fs_follower.clone(), Box::new(FsTransport::new(&leader_dir)));
        fs_repl.sync_once(None).unwrap();
        // HTTP follower through a sync-only frontend on the leader.
        let frontend =
            HttpFrontend::start("127.0.0.1:0", None, leader.clone(), FrontConfig::default())
                .unwrap();
        let http_follower = Arc::new(VariantRegistry::open(&http_dir).unwrap());
        let http_repl = Replicator::new(
            http_follower.clone(),
            Box::new(HttpTransport::new(&frontend.url()).unwrap()),
        );
        http_repl.sync_once(None).unwrap();

        for name in ["mx@1", "mx@2", "mx"] {
            let want = logits_of(&base, &leader_dir, name, &tokens);
            assert_eq!(want, logits_of(&base, &fs_dir, name, &tokens), "fs logits for '{name}'");
            assert_eq!(
                want,
                logits_of(&base, &http_dir, name, &tokens),
                "http logits for '{name}'"
            );
        }

        // Consolidate the chain on the leader; both followers converge to
        // the full artifact and still serve bitwise-identical logits.
        leader.consolidate("mx", Some(2)).unwrap();
        fs_repl.sync_once(None).unwrap();
        http_repl.sync_once(None).unwrap();
        for (label, dir) in [("fs", &fs_dir), ("http", &http_dir)] {
            let r = VariantRegistry::open(dir).unwrap().resolve("mx").unwrap();
            assert_eq!((r.version, r.patch), (2, false), "{label} follower consolidated state");
        }
        let want = logits_of(&base, &leader_dir, "mx", &tokens);
        assert_eq!(want, logits_of(&base, &fs_dir, "mx", &tokens));
        assert_eq!(want, logits_of(&base, &http_dir, "mx", &tokens));
    });
}

#[test]
fn file_sizes_reported_by_sync_match_disk() {
    // Cross-check SyncReport byte accounting against the actual files — the
    // bench's wire-counter gate builds on this equivalence.
    let leader_dir = fresh_dir("pawd_itest_repl_bytes_leader");
    let follower_dir = fresh_dir("pawd_itest_repl_bytes_follower");
    let cfg = ModelConfig::preset("tiny").unwrap();
    let base = Arc::new(FlatParams::init(&cfg, 37));
    let leader = VariantRegistry::open(&leader_dir).unwrap();
    leader.publish("ft", seeded_full(&base, "ft", 71)).unwrap();
    let on_disk: u64 = leader
        .list()
        .iter()
        .flat_map(|d| d.versions.iter())
        .map(|v| file_size(&leader_dir.join(&v.file)))
        .sum();
    let follower = Arc::new(VariantRegistry::open(&follower_dir).unwrap());
    let repl = Replicator::new(follower, Box::new(FsTransport::new(&leader_dir)));
    let report = repl.sync_once(None).unwrap();
    assert_eq!(report.artifact_bytes, on_disk);
    assert_eq!(report.manifest_bytes, file_size(&leader_dir.join("registry.json")));
}
