//! Cross-window prefix cache: resumed forwards must be *bitwise* equal to
//! cold ones at every pool width, the cache must hit across windows and
//! variants, eviction must respect the byte budget, and — the publish-path
//! invariant — a delta publish must NOT invalidate resident prefix state
//! (new weights mint new identity keys; old entries simply age out).

mod common;

use common::{fresh_dir, seeded_full, with_timeout};
use pawd::coordinator::{Engine, RespBody, Server, ServerConfig, VariantStore};
use pawd::delta::format::save_delta;
use pawd::delta::types::Axis;
use pawd::exec::{
    pool, prefix, BatchPlan, ExecMode, PackedVariant, PrefixCache, VariantWeights, Weights,
};
use pawd::model::config::ModelConfig;
use pawd::model::{FlatParams, Transformer};
use pawd::tensor::Tensor2;
use std::sync::Arc;

fn bits(t: &Tensor2) -> Vec<u32> {
    t.data.iter().map(|x| x.to_bits()).collect()
}

fn mk_fleet(n: usize) -> (Arc<FlatParams>, Vec<VariantWeights>) {
    let cfg = ModelConfig::preset("tiny").unwrap();
    let base = Arc::new(FlatParams::init(&cfg, 321));
    let variants = (0..n)
        .map(|k| {
            let delta = seeded_full(&base, &format!("var{k}"), 50 + k as u64, &[Axis::Row]);
            VariantWeights::Packed(PackedVariant::new(base.clone(), Arc::new(delta)).unwrap())
        })
        .collect();
    (base, variants)
}

fn check_capture_resume<W: Weights>(tf: &Transformer, w: &W, tokens: &[u8], cand: usize) {
    let cold = tf.forward_one(w, tokens);
    let (warm, cap) = tf.forward_one_prefixed(w, tokens, None, cand);
    assert_eq!(bits(&cold), bits(&warm), "capture pass diverged (len {})", tokens.len());
    let state = cap.expect("capture requested");
    assert_eq!(state.len(), cand);
    let (resumed, none) = tf.forward_one_prefixed(w, tokens, Some(&state), 0);
    assert!(none.is_none());
    assert_eq!(bits(&cold), bits(&resumed), "resume diverged (len {})", tokens.len());
    // A different continuation of the same prefix resumes bitwise too.
    let mut other = tokens[..cand].to_vec();
    other.extend((0..5).map(|t| 97 + t as u8));
    let cold2 = tf.forward_one(w, &other);
    let (resumed2, _) = tf.forward_one_prefixed(w, &other, Some(&state), 0);
    assert_eq!(bits(&cold2), bits(&resumed2), "cross-suffix resume diverged");
}

/// Property: capture-then-resume is bitwise-equal to the cold forward, for
/// base and packed-variant weights, at serial and parallel pool widths.
#[test]
fn capture_then_resume_is_bitwise_equal_to_cold_at_all_pool_widths() {
    let (base, variants) = mk_fleet(1);
    let tf = Transformer::new(base.cfg());
    let mk_tokens =
        |len: usize| -> Vec<u8> { (0..len).map(|t| ((t * 13 + 7) % 200 + 20) as u8).collect() };
    for width in [1usize, 4] {
        pool::with_thread_limit(width, || {
            for len in [9usize, 16, 24, 33] {
                let tokens = mk_tokens(len);
                let cand = (len - 1) / 8 * 8;
                check_capture_resume(&tf, &*base, &tokens, cand);
                check_capture_resume(&tf, &variants[0], &tokens, cand);
            }
        });
    }
}

/// A mixed-variant window through [`prefix::run_plan`] is bitwise-equal to
/// the cold `forward_plan`, and the second pass over the same window hits
/// the cache for every sequence — at serial and parallel pool widths.
#[test]
fn run_plan_mixed_window_is_bitwise_equal_and_hits_on_second_pass() {
    let (base, variants) = mk_fleet(3);
    let tf = Transformer::new(base.cfg());
    let batch_weights: Vec<VariantWeights> = (0..6).map(|i| variants[i % 3].clone()).collect();
    let plans = BatchPlan::group(&batch_weights);
    assert_eq!(plans.len(), 1, "packed variants of one base share one plan");
    let (plan, _members) = &plans[0];
    // All six requests share a 16-token prefix; two requests per variant, so
    // each variant's pair forms one cacheable group.
    let shared: Vec<u8> = (0..16).map(|t| 40 + t as u8).collect();
    let seqs: Vec<(usize, Vec<u8>)> = (0..6)
        .map(|i| {
            let mut t = shared.clone();
            t.extend((0..6).map(|s| (100 + (s * 3 + i * 17) % 80) as u8));
            (i, t)
        })
        .collect();
    let cold = tf.forward_plan(plan, &seqs);
    for width in [1usize, 4] {
        pool::with_thread_limit(width, || {
            let cache = PrefixCache::with_budget(64 << 20);
            let warm = prefix::run_plan(&tf, plan, &seqs, &cache);
            assert!(!cache.is_empty(), "width {width}: warm pass captured nothing");
            let hot = prefix::run_plan(&tf, plan, &seqs, &cache);
            let s = cache.stats();
            assert!(s.hits >= seqs.len() as u64, "width {width}: {s:?}");
            assert!(s.rows_skipped > 0, "width {width}: {s:?}");
            for ((c, w), h) in cold.iter().zip(&warm).zip(&hot) {
                assert_eq!(bits(c), bits(w), "width {width}: warm pass diverged");
                assert_eq!(bits(c), bits(h), "width {width}: hit pass diverged");
            }
        });
    }
}

/// Under byte-budget pressure the cache evicts (LRU) but never exceeds its
/// budget, and evictions never change results.
#[test]
fn eviction_pressure_respects_budget_and_stays_exact() {
    let (base, variants) = mk_fleet(1);
    let tf = Transformer::new(base.cfg());
    let plans = BatchPlan::group(&variants);
    let (plan, _members) = &plans[0];
    // A 24-token prefix state on `tiny` is ~49 KB (2 layers of K/V rows
    // plus prefix logits); this budget holds two of them, not three.
    let cache = PrefixCache::with_budget(120_000);
    for round in 0..6u8 {
        let prefix_bytes: Vec<u8> = (0..24).map(|t| 20 + round * 9 + t as u8).collect();
        let seqs: Vec<(usize, Vec<u8>)> = (0..2)
            .map(|i| {
                let mut t = prefix_bytes.clone();
                t.push(200 + round * 2 + i as u8);
                (0, t)
            })
            .collect();
        let cold = tf.forward_plan(plan, &seqs);
        let got = prefix::run_plan(&tf, plan, &seqs, &cache);
        for (c, g) in cold.iter().zip(&got) {
            assert_eq!(bits(c), bits(g), "round {round}: eviction changed results");
        }
        assert!(
            cache.used_bytes() <= cache.budget_bytes(),
            "round {round}: {} bytes resident exceeds the {} budget",
            cache.used_bytes(),
            cache.budget_bytes()
        );
    }
    assert!(
        (1..=2).contains(&cache.len()),
        "budget holds at most two states, got {}",
        cache.len()
    );
    assert!(cache.stats().misses >= 5, "distinct prefixes must miss: {:?}", cache.stats());
}

/// The serving-stack invariant: `publish_incremental` must NOT invalidate
/// resident prefix state. A publish mints a new delta `Arc` (a new weights
/// identity), so old entries stay resident until they age out, untouched
/// variants keep serving bitwise-identical results, and the republished
/// variant serves its new version.
#[test]
fn publish_incremental_does_not_invalidate_the_prefix_cache() {
    with_timeout("publish_non_invalidation", 120, || {
        let dir = fresh_dir("pawd_itest_prefix_publish");
        let cfg = ModelConfig::preset("tiny").unwrap();
        let base = Arc::new(FlatParams::init(&cfg, 77));
        for k in 0..2u64 {
            let delta = seeded_full(&base, &format!("var{k}"), 400 + k, &[Axis::Row]);
            save_delta(dir.join(format!("var{k}.pawd")), &delta).unwrap();
        }
        let store = VariantStore::new(base.clone(), &dir).with_mode(ExecMode::Fused);
        let server = Server::start(store, Engine::Native, ServerConfig::default());
        let client = server.client();
        // CI also runs the suite with the kill switch set; the publish and
        // bitwise-stability asserts still hold there, only the
        // cache-activity ones are skipped.
        let cache_on = std::env::var("PAWD_PREFIX_CACHE").ok().as_deref() != Some("0");
        let choices = vec!["alpha".to_string(), "beta".to_string(), "gamma".to_string()];
        let prompt = "Q: does the prefix cache survive a delta publish? A: ";
        let score = |variant: &str| -> Vec<f64> {
            let resp = client.score(variant, prompt, &choices);
            match resp.result {
                Ok(RespBody::Score { scores, .. }) => scores,
                other => panic!("unexpected {other:?}"),
            }
        };
        let v1_before = score("var1");
        for _ in 0..2 {
            score("var0");
            score("var1");
        }
        if cache_on {
            assert!(server.prefix.used_bytes() > 0, "serving must populate the prefix cache");
        }
        let (used_before, len_before) = (server.prefix.used_bytes(), server.prefix.len());

        let v2 = seeded_full(&base, "var0", 999, &[Axis::Row]);
        let staged = dir.join("var0_v2.pawd");
        save_delta(&staged, &v2).unwrap();
        let (new_version, _, _) = client.publish_incremental("var0", &staged, None).unwrap();
        assert_eq!(
            server.prefix.used_bytes(),
            used_before,
            "publish must not evict prefix state"
        );
        assert_eq!(server.prefix.len(), len_before, "publish must not drop entries");

        let v1_after = score("var1");
        let fbits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(
            fbits(&v1_before),
            fbits(&v1_after),
            "untouched variant must stay bitwise-identical across a publish"
        );
        let resp = client.score("var0", prompt, &choices);
        assert!(resp.result.is_ok(), "republished variant failed: {:?}", resp.result);
        assert_eq!(resp.version, Some(new_version));
        server.shutdown();
    });
}
