//! Cross-layer integration: the JAX-lowered AOT artifacts executed through
//! the PJRT runtime must agree with (a) the golden jax logits in the parity
//! fixture and (b) the native Rust transformer — proving all three
//! implementations of the model (Rust, JAX, compiled HLO) coincide, and the
//! Pallas kernel artifacts match the native delta apply.
//!
//! Requires `make artifacts` (skips politely otherwise).

use pawd::delta::pack::PackedMask;
use pawd::delta::types::{Axis, Codec, DeltaModule};
use pawd::model::{FlatParams, ModelConfig, ModuleId, ProjKind, Transformer};
use pawd::runtime::{self, HostTensor};
use pawd::tensor::Tensor2;
use std::path::PathBuf;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

/// Parity fixture written by aot.py: params, tokens, and jax logits.
struct Parity {
    params: Vec<f32>,
    tokens: Vec<Vec<u8>>,
    logits: Vec<f32>, // [B, T, V]
    b: usize,
    t: usize,
    v: usize,
}

fn load_parity() -> Parity {
    let raw = std::fs::read(artifacts_dir().join("parity_tiny.bin")).expect("parity fixture");
    let mut off = 0usize;
    let rd_u32 = |raw: &[u8], off: &mut usize| {
        let v = u32::from_le_bytes(raw[*off..*off + 4].try_into().unwrap());
        *off += 4;
        v as usize
    };
    let p = rd_u32(&raw, &mut off);
    let params: Vec<f32> = raw[off..off + 4 * p]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    off += 4 * p;
    let b = rd_u32(&raw, &mut off);
    let t = rd_u32(&raw, &mut off);
    let tokens_flat: Vec<i32> = raw[off..off + 4 * b * t]
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    off += 4 * b * t;
    let v = rd_u32(&raw, &mut off);
    let logits: Vec<f32> = raw[off..off + 4 * b * t * v]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    off += 4 * b * t * v;
    assert_eq!(off, raw.len());
    let tokens = (0..b)
        .map(|i| tokens_flat[i * t..(i + 1) * t].iter().map(|&x| x as u8).collect())
        .collect();
    Parity { params, tokens, logits, b, t, v }
}

fn close(a: &[f32], b: &[f32], atol: f32, rtol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    let mut worst = 0f32;
    for (&x, &y) in a.iter().zip(b) {
        let tol = atol + rtol * y.abs().max(x.abs());
        let d = (x - y).abs();
        if d > tol && d > worst {
            worst = d;
        }
    }
    assert!(worst == 0.0, "{what}: worst abs deviation {worst}");
}

#[test]
fn native_forward_matches_jax_fixture() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let fx = load_parity();
    let cfg = ModelConfig::preset("tiny").unwrap();
    let mut params = FlatParams::zeros(&cfg);
    params.data.copy_from_slice(&fx.params);
    let tf = Transformer::new(&cfg);
    for (i, seq) in fx.tokens.iter().enumerate() {
        let logits = tf.forward_one(&params, seq);
        let want = &fx.logits[i * fx.t * fx.v..(i + 1) * fx.t * fx.v];
        close(&logits.data, want, 2e-3, 2e-3, &format!("native vs jax, seq {i}"));
    }
}

#[test]
fn pjrt_forward_matches_jax_fixture() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let fx = load_parity();
    let h = runtime::start(&artifacts_dir()).expect("runtime");
    let logits = runtime::forward_logits(&h, "tiny", &fx.params, &fx.tokens).expect("forward");
    assert_eq!(logits.len(), fx.b);
    for (i, l) in logits.iter().enumerate() {
        assert_eq!((l.rows, l.cols), (fx.t, fx.v));
        let want = &fx.logits[i * fx.t * fx.v..(i + 1) * fx.t * fx.v];
        close(&l.data, want, 1e-4, 1e-4, &format!("pjrt vs jax, seq {i}"));
    }
    h.shutdown();
}

#[test]
fn bucketed_forward_pads_correctly() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let fx = load_parity();
    let h = runtime::start(&artifacts_dir()).expect("runtime");
    // Short sequences must produce the same logits as their full-bucket run
    // (causality + right-padding policy).
    let short: Vec<Vec<u8>> = vec![fx.tokens[0][..10].to_vec()];
    let got = runtime::forward_logits(&h, "tiny", &fx.params, &short).expect("fwd");
    let want = &fx.logits[..10 * fx.v]; // first sequence, first 10 positions
    close(&got[0].data, want, 1e-4, 1e-4, "padded short seq");
    // Over-capacity requests fail cleanly.
    let too_big: Vec<Vec<u8>> = (0..64).map(|_| vec![1u8; 8]).collect();
    assert!(runtime::forward_logits(&h, "tiny", &fx.params, &too_big).is_err());
    h.shutdown();
}

#[test]
fn shape_validation_rejects_bad_inputs() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let h = runtime::start(&artifacts_dir()).expect("runtime");
    // Wrong arity.
    assert!(h.run("fwd_tiny_b1_t48", vec![]).is_err());
    // Wrong dtype.
    let bad = vec![
        HostTensor::I32(vec![0; 10], vec![10]),
        HostTensor::I32(vec![0; 48], vec![1, 48]),
    ];
    assert!(h.run("fwd_tiny_b1_t48", bad).is_err());
    // Unknown program.
    assert!(h.run("nonexistent", vec![]).is_err());
    h.shutdown();
}

#[test]
fn train_step_reduces_loss_from_rust() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let h = runtime::start(&artifacts_dir()).expect("runtime");
    let spec = h.manifest().find_kind("train_step", "tiny").expect("train bucket").clone();
    let (b, t1) = (spec.batch.unwrap(), spec.seq.unwrap() + 1);
    let cfg = ModelConfig::preset("tiny").unwrap();
    let init = FlatParams::init(&cfg, 7);
    let mut state = runtime::TrainState::new(init.data.clone());
    let windows: Vec<Vec<u8>> = (0..b)
        .map(|i| (0..t1).map(|j| ((i * 31 + j * 7) % 200 + 1) as u8).collect())
        .collect();
    let mut losses = Vec::new();
    for _ in 0..30 {
        losses.push(runtime::train_step(&h, "tiny", &mut state, &windows, 3e-3).expect("step"));
    }
    assert!(losses.iter().all(|l| l.is_finite()));
    assert!(
        losses[29] < losses[0] * 0.8,
        "loss should fall: first {} last {}",
        losses[0],
        losses[29]
    );
    assert_eq!(state.step, 30);
    assert_ne!(state.params, init.data);
    h.shutdown();
}

#[test]
fn lmgrad_is_zero_at_teacher_and_descends() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let h = runtime::start(&artifacts_dir()).expect("runtime");
    let spec = h.manifest().find_kind("lmgrad", "tiny").expect("lmgrad").clone();
    let (b, t) = (spec.batch.unwrap(), spec.seq.unwrap());
    let cfg = ModelConfig::preset("tiny").unwrap();
    let teacher = FlatParams::init(&cfg, 3);
    let student = FlatParams::init(&cfg, 4);
    let seqs: Vec<Vec<u8>> =
        (0..b).map(|i| (0..t).map(|j| ((i * 13 + j * 3) % 250 + 1) as u8).collect()).collect();
    // Teacher logits via the runtime forward (same bucket shape).
    let tl = runtime::forward_logits(&h, "tiny", &teacher.data, &seqs).expect("teacher fwd");
    let mut teacher_flat = Vec::with_capacity(b * t * cfg.vocab);
    for l in &tl {
        teacher_flat.extend_from_slice(&l.data);
    }
    // Zero at the teacher itself.
    let (loss0, g0) =
        runtime::lmgrad(&h, "tiny", &teacher.data, &seqs, &teacher_flat).expect("lmgrad");
    assert!(loss0 < 1e-9, "loss at teacher = {loss0}");
    assert!(g0.iter().all(|g| g.abs() < 1e-3));
    // Descends from the student.
    let (loss1, g1) =
        runtime::lmgrad(&h, "tiny", &student.data, &seqs, &teacher_flat).expect("lmgrad");
    assert!(loss1 > 0.0);
    let stepped: Vec<f32> = student.data.iter().zip(&g1).map(|(p, g)| p - 0.05 * g).collect();
    let (loss2, _) = runtime::lmgrad(&h, "tiny", &stepped, &seqs, &teacher_flat).expect("lmgrad");
    assert!(loss2 < loss1, "{loss2} !< {loss1}");
    h.shutdown();
}

#[test]
fn pallas_delta_apply_matches_native() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let h = runtime::start(&artifacts_dir()).expect("runtime");
    let cfg = ModelConfig::preset("tiny").unwrap();
    let (d_out, d_in) = ProjKind::Up.shape(&cfg); // 128 x 64
    let mut rng = pawd::util::rng::Rng::new(5);
    let base: Vec<f32> = (0..d_out * d_in).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let delta: Vec<f32> = (0..d_out * d_in).map(|_| rng.normal_f32(0.0, 0.1)).collect();
    let mask = PackedMask::pack(&delta, d_out, d_in);
    for (axis_name, axis) in [("row", Axis::Row), ("col", Axis::Col)] {
        let n = axis.n_scales(d_out, d_in);
        let scales: Vec<f32> = (0..n).map(|_| rng.uniform_in(0.01, 0.3)).collect();
        let module = DeltaModule {
            id: ModuleId { layer: 0, kind: ProjKind::Up },
            mask: mask.clone(),
            axis,
            scales: scales.clone(),
            codec: Codec::PerAxis,
        };
        let mut native = vec![0f32; base.len()];
        pawd::delta::apply::apply_module_into(&base, &mut native, &module);
        let xla_out = runtime::api::delta_apply_xla(
            &h, axis_name, &base, d_out, d_in, &mask.words, &scales,
        )
        .expect("xla apply");
        close(&native, &xla_out, 1e-6, 1e-6, &format!("delta_apply {axis_name}"));
    }
    h.shutdown();
}

#[test]
fn pallas_fused_matmul_matches_native_gemm() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let h = runtime::start(&artifacts_dir()).expect("runtime");
    let cfg = ModelConfig::preset("tiny").unwrap();
    let (d_out, d_in) = ProjKind::Q.shape(&cfg); // 64 x 64
    let n = 64; // FUSED_N in aot.py
    let mut rng = pawd::util::rng::Rng::new(6);
    let base: Vec<f32> = (0..d_out * d_in).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let delta: Vec<f32> = (0..d_out * d_in).map(|_| rng.normal_f32(0.0, 0.1)).collect();
    let mask = PackedMask::pack(&delta, d_out, d_in);
    let x: Vec<f32> = (0..n * d_in).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let scales: Vec<f32> = (0..d_out).map(|_| rng.uniform_in(0.01, 0.3)).collect();
    let module = DeltaModule {
        id: ModuleId { layer: 0, kind: ProjKind::Q },
        mask: mask.clone(),
        axis: Axis::Row,
        scales: scales.clone(),
        codec: Codec::PerAxis,
    };
    // Native: materialize then GEMM.
    let mut w = vec![0f32; base.len()];
    pawd::delta::apply::apply_module_into(&base, &mut w, &module);
    let xt = Tensor2::from_vec(n, d_in, x.clone());
    let wt = Tensor2::from_vec(d_out, d_in, w);
    let want = xt.matmul_bt(&wt);
    let got = runtime::api::fused_delta_matmul_xla(
        &h, "row", &x, n, &base, d_out, d_in, &mask.words, &scales,
    )
    .expect("fused");
    close(&want.data, &got, 1e-3, 1e-3, "fused matmul");
    h.shutdown();
}
