//! Seeded-violation fixtures for the audit passes.
//!
//! Each file under `tests/audit_fixtures/` carries exactly one deliberate
//! violation, and each must surface as exactly one finding with its stable
//! code — proving the passes fire (the self-audit only proves they stay
//! quiet). The fixtures are excluded from the repo audit by path segment
//! and are never compiled (cargo only builds top-level files in `tests/`).

use pawd::audit::{drift, lexer, matches, unsafety, uses, SourceTree};
use std::collections::{BTreeMap, BTreeSet};
use std::path::PathBuf;

fn fixture_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/audit_fixtures")
}

fn snippet(name: &str) -> String {
    let p = fixture_dir().join("snippets").join(name);
    std::fs::read_to_string(&p).unwrap_or_else(|e| panic!("reading {}: {e}", p.display()))
}

/// The one-and-only-one contract every fixture is held to.
fn expect_single(findings: &[pawd::audit::Finding], code: &str, msg_fragment: &str) {
    assert_eq!(
        findings.len(),
        1,
        "expected exactly one {code} finding, got: {:?}",
        findings.iter().map(|f| f.to_string()).collect::<Vec<_>>()
    );
    assert_eq!(findings[0].code, code);
    assert!(
        findings[0].message.contains(msg_fragment),
        "finding message {:?} missing fragment {msg_fragment:?}",
        findings[0].message
    );
}

#[test]
fn unbalanced_snippet_yields_one_a001() {
    let src = snippet("unbalanced.rs");
    expect_single(&lexer::balance_one("unbalanced.rs", &src), "A001", "{");
}

#[test]
fn missing_safety_snippet_yields_one_a201() {
    let src = snippet("missing_safety.rs");
    expect_single(&unsafety::check_safety_comments("missing_safety.rs", &src), "A201", "SAFETY");
}

#[test]
fn nonexhaustive_match_snippet_yields_one_a003() {
    let src = snippet("nonexhaustive_match.rs");
    // The fixture declares its own grown enum; build the variant table the
    // same way the repo pass does.
    let variants = matches::enum_variants(&src, "Fruit").expect("Fruit enum parses");
    assert_eq!(variants, ["Apple", "Banana", "Cherry"]);
    let mut enums: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
    enums.insert("Fruit".to_string(), variants.into_iter().collect());
    expect_single(
        &matches::check_file("nonexhaustive_match.rs", &src, &enums),
        "A003",
        "Cherry",
    );
}

#[test]
fn condvar_snippet_yields_one_a203() {
    let src = snippet("condvar_no_loop.rs");
    expect_single(&unsafety::check_condvar_waits("condvar_no_loop.rs", &src), "A203", "loop");
}

#[test]
fn mini_use_tree_yields_one_a002() {
    let tree = SourceTree::load(&fixture_dir().join("mini_use")).expect("fixture tree loads");
    let findings = uses::pass_use_resolution(&tree);
    expect_single(&findings, "A002", "Missing");
    assert_eq!(findings[0].file, "rust/src/lib.rs");
}

#[test]
fn mini_drift_tree_yields_one_a101() {
    let tree = SourceTree::load(&fixture_dir().join("mini_drift")).expect("fixture tree loads");
    let findings = drift::pass_counter_drift(&tree).expect("pass runs");
    expect_single(&findings, "A101", "README counter table");
    assert_eq!(findings[0].file, "README.md");
}
