//! Loopback integration for the HTTP network plane: the data plane answers
//! bitwise-identically to the in-process client, the admin plane round-trips
//! typed ops, and `HttpTransport` followers converge exactly like
//! `FsTransport` ones — with idle long-polls costing header bytes only.
//!
//! Every test that touches a socket runs under `common::with_timeout` so a
//! wedged connection fails the test instead of hanging the suite.

mod common;

use common::{fresh_dir, with_timeout};
use pawd::coordinator::{
    AdminOp, AdminResp, Engine, FsTransport, Replicator, Server, ServerConfig, VariantRegistry,
    VariantStore,
};
use pawd::delta::types::{Axis, DeltaModel};
use pawd::exec::ExecMode;
use pawd::model::config::ModelConfig;
use pawd::model::{FlatParams, Transformer};
use pawd::net::{FrontConfig, HttpApiClient, HttpFrontend, HttpTransport};
use pawd::util::crc32;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn seeded_full(base: &FlatParams, variant: &str, seed: u64) -> DeltaModel {
    common::seeded_full(base, variant, seed, &[Axis::Row])
}

/// `model` with module `k` replaced by freshly seeded content.
fn perturb_one(model: &DeltaModel, base: &FlatParams, k: usize, seed: u64) -> DeltaModel {
    let mut out = model.clone();
    let fresh = seeded_full(base, &model.variant, seed);
    out.modules[k] = fresh.modules[k].clone();
    out
}

/// Bitwise logits of `name` (active version) served fused from `dir`.
fn logits_of(base: &Arc<FlatParams>, dir: &Path, name: &str, tokens: &[u8]) -> Vec<u32> {
    let store = VariantStore::new(base.clone(), dir).with_mode(ExecMode::Fused);
    let tf = Transformer::new(base.cfg());
    let loaded = store.load(name).unwrap();
    tf.forward_one(&loaded.weights, tokens).data.iter().map(|x| x.to_bits()).collect()
}

/// One raw HTTP exchange: write `req` bytes, half-close, read until the
/// server closes. Lossy-decoded for assertions.
fn raw_exchange(addr: std::net::SocketAddr, req: &[u8]) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    s.write_all(req).unwrap();
    s.shutdown(std::net::Shutdown::Write).unwrap();
    let mut buf = Vec::new();
    let _ = s.read_to_end(&mut buf);
    String::from_utf8_lossy(&buf).into_owned()
}

#[test]
fn http_query_is_bitwise_equal_to_in_process() {
    with_timeout("http_query_bitwise", 120, || {
        let dir = fresh_dir("pawd_itest_http_query");
        let cfg = ModelConfig::preset("tiny").unwrap();
        let base = Arc::new(FlatParams::init(&cfg, 101));
        let registry = VariantRegistry::open(&dir).unwrap();
        registry.publish("ft", seeded_full(&base, "ft", 5)).unwrap();
        drop(registry);

        let store = VariantStore::new(base, &dir).with_mode(ExecMode::Fused);
        let server = Server::start(store, Engine::Native, ServerConfig::default());
        let frontend = HttpFrontend::start(
            "127.0.0.1:0",
            Some(server.client()),
            server.cache.store().registry().clone(),
            FrontConfig::default(),
        )
        .unwrap();
        let api = HttpApiClient::new(&frontend.url()).unwrap();
        let client = server.client();

        let prompt = "Q: is the network plane exact? A: ";
        let choices: Vec<String> = vec!["yes".into(), "no".into(), "maybe".into()];
        let local = client.score("ft", prompt, &choices);
        let local_body = local.result.clone().unwrap();
        let remote = api.score("ft", prompt, &choices).unwrap();
        assert_eq!(remote.variant, "ft");
        assert_eq!(remote.version, local.version);
        match (&remote.body, &local_body) {
            (
                pawd::coordinator::RespBody::Score { choice: rc, scores: rs },
                pawd::coordinator::RespBody::Score { choice: lc, scores: ls },
            ) => {
                assert_eq!(rc, lc);
                let rbits: Vec<u64> = rs.iter().map(|x| x.to_bits()).collect();
                let lbits: Vec<u64> = ls.iter().map(|x| x.to_bits()).collect();
                assert_eq!(rbits, lbits, "HTTP scores must be bitwise-equal to in-process");
            }
            other => panic!("unexpected bodies {other:?}"),
        }

        // Perplexity rides the same f64-exact transport.
        let local = client.submit("ft", pawd::coordinator::Payload::perplexity("exactness test"));
        let local = local.recv().unwrap().result.unwrap();
        let remote = api.perplexity("ft", "exactness test").unwrap();
        match (&remote.body, &local) {
            (
                pawd::coordinator::RespBody::Perplexity { nats_per_token: r },
                pawd::coordinator::RespBody::Perplexity { nats_per_token: l },
            ) => assert_eq!(r.to_bits(), l.to_bits()),
            other => panic!("unexpected bodies {other:?}"),
        }

        // Engine-level rejections surface as Err with the engine's message.
        let err = api.score("missing-variant", "Q", &choices).unwrap_err().to_string();
        assert!(!err.is_empty());

        server.shutdown();
    })
}

#[test]
fn http_admin_plane_round_trips_typed_ops() {
    with_timeout("http_admin_roundtrip", 120, || {
        let dir = fresh_dir("pawd_itest_http_admin");
        let cfg = ModelConfig::preset("tiny").unwrap();
        let base = Arc::new(FlatParams::init(&cfg, 103));
        let registry = VariantRegistry::open(&dir).unwrap();
        registry.publish("ft", seeded_full(&base, "ft", 9)).unwrap();
        registry.publish("ft", seeded_full(&base, "ft", 10)).unwrap();
        drop(registry);

        let store = VariantStore::new(base, &dir).with_mode(ExecMode::Fused);
        let server = Server::start(store, Engine::Native, ServerConfig::default());
        let frontend = HttpFrontend::start(
            "127.0.0.1:0",
            Some(server.client()),
            server.cache.store().registry().clone(),
            FrontConfig::default(),
        )
        .unwrap();
        let api = HttpApiClient::new(&frontend.url()).unwrap();
        api.health().unwrap();

        match api.admin(&AdminOp::List).unwrap() {
            AdminResp::Variants { variants } => {
                assert_eq!(variants.len(), 1);
                assert_eq!(variants[0].name, "ft");
                assert_eq!(variants[0].versions.len(), 2);
            }
            other => panic!("unexpected list response {other:?}"),
        }
        match api.admin(&AdminOp::SyncStatus).unwrap() {
            AdminResp::SyncStatus { manifest_seq, variants, versions } => {
                assert!(manifest_seq > 0);
                assert_eq!((variants, versions), (1, 2));
            }
            other => panic!("unexpected sync-status response {other:?}"),
        }
        match api.admin(&AdminOp::Rollback { variant: "ft".into(), to: None }).unwrap() {
            AdminResp::RolledBack { variant, version } => {
                assert_eq!((variant.as_str(), version), ("ft", 1));
            }
            other => panic!("unexpected rollback response {other:?}"),
        }
        // Stats over HTTP include the http counters this very conversation
        // has been incrementing.
        let snap = api.stats().unwrap();
        assert!(snap.http_requests >= 4, "stats must count these requests");

        // A bogus admin route is a 400, not a hang or a panic.
        let resp = raw_exchange(
            frontend.addr(),
            b"POST /v1/admin/frobnicate HTTP/1.1\r\nHost: t\r\nContent-Length: 2\r\n\r\n{}",
        );
        assert!(resp.starts_with("HTTP/1.1 400"), "got: {resp}");

        server.shutdown();
    })
}

/// Drive the whole [`ApiClient`](pawd::coordinator::ApiClient) surface
/// through the trait (dyn, so nothing resolves to inherent methods) and
/// return comparable bits.
fn exercise_api(c: &dyn pawd::coordinator::ApiClient) -> (usize, Vec<u64>, u64) {
    c.health().unwrap();
    let choices: Vec<String> = vec!["yes".into(), "no".into()];
    let score = c.score("ft", "Q: one surface, two transports? A: ", &choices).unwrap();
    assert_eq!(score.variant, "ft");
    let (choice, score_bits) = match score.body {
        pawd::coordinator::RespBody::Score { choice, scores } => {
            (choice, scores.iter().map(|x| x.to_bits()).collect::<Vec<u64>>())
        }
        other => panic!("unexpected score body {other:?}"),
    };
    let ppl = c.perplexity("ft", "trait parity probe").unwrap();
    let ppl_bits = match ppl.body {
        pawd::coordinator::RespBody::Perplexity { nats_per_token } => nats_per_token.to_bits(),
        other => panic!("unexpected perplexity body {other:?}"),
    };
    // stats() is the trait's default impl — it must ride the admin lane of
    // whichever transport `c` is.
    assert!(c.stats().unwrap().served >= 1);
    assert!(c.admin(AdminOp::List).is_ok());
    // Engine rejections surface on the shared String error lane.
    assert!(c.score("no-such-variant", "Q", &choices).is_err());
    (choice, score_bits, ppl_bits)
}

#[test]
fn api_client_trait_unifies_local_and_http() {
    with_timeout("api_client_trait", 120, || {
        let dir = fresh_dir("pawd_itest_api_trait");
        let cfg = ModelConfig::preset("tiny").unwrap();
        let base = Arc::new(FlatParams::init(&cfg, 113));
        let registry = VariantRegistry::open(&dir).unwrap();
        registry.publish("ft", seeded_full(&base, "ft", 21)).unwrap();
        drop(registry);

        let store = VariantStore::new(base, &dir).with_mode(ExecMode::Fused);
        let server = Server::start(store, Engine::Native, ServerConfig::default());
        let frontend = HttpFrontend::start(
            "127.0.0.1:0",
            Some(server.client()),
            server.cache.store().registry().clone(),
            FrontConfig::default(),
        )
        .unwrap();
        let api = HttpApiClient::new(&frontend.url()).unwrap();
        let client = server.client();

        let local = exercise_api(&client);
        let remote = exercise_api(&api);
        assert_eq!(local, remote, "trait surface must be bitwise-identical across transports");

        server.shutdown();
    })
}

#[test]
fn http_transport_follower_converges_bitwise() {
    with_timeout("http_transport_converges", 180, || {
        let leader_dir = fresh_dir("pawd_itest_http_sync_leader");
        let follower_dir = fresh_dir("pawd_itest_http_sync_follower");
        let cfg = ModelConfig::preset("tiny").unwrap();
        let base = Arc::new(FlatParams::init(&cfg, 107));
        let leader = Arc::new(VariantRegistry::open(&leader_dir).unwrap());
        let v1 = seeded_full(&base, "ft", 61);
        let full = leader.publish_incremental("ft", v1.clone(), None).unwrap();
        let v2 = perturb_one(&v1, &base, 2, 91);
        let out2 = leader.publish_incremental("ft", v2, None).unwrap();
        assert!(out2.patch);
        leader.publish("other", seeded_full(&base, "other", 77)).unwrap();

        // Sync-only frontend: no engine attached, just the leader registry.
        let frontend =
            HttpFrontend::start("127.0.0.1:0", None, leader.clone(), FrontConfig::default())
                .unwrap();
        let follower = Arc::new(VariantRegistry::open(&follower_dir).unwrap());
        let repl = Replicator::new(
            follower.clone(),
            Box::new(HttpTransport::new(&frontend.url()).unwrap()),
        );

        // Cold sync over HTTP: same structure as the FsTransport suite.
        let report = repl.sync_once(None).unwrap();
        assert!(!report.up_to_date);
        assert_eq!(report.variants_synced, 2);
        assert_eq!(report.versions_installed, 3);
        assert_eq!(report.files_fetched, 3);
        assert_eq!(report.patch_files_fetched, 1);
        assert_eq!(report.leader_seq, leader.manifest_seq());
        let tokens: Vec<u8> = (0..12u8).map(|t| t.wrapping_mul(23) % 200 + 10).collect();
        for name in ["ft", "ft@1", "ft@2", "other"] {
            assert_eq!(
                logits_of(&base, &leader_dir, name, &tokens),
                logits_of(&base, &follower_dir, name, &tokens),
                "HTTP-synced follower must serve bitwise-identical logits for '{name}'"
            );
        }

        // Warm patch publish: the follower moves only the patch (plus HTTP
        // header overhead), well under the consolidated artifact.
        let v3 = perturb_one(&v1, &base, 0, 191);
        let out3 = leader.publish_incremental("ft", v3, None).unwrap();
        assert!(out3.patch);
        let report = repl.sync_once(None).unwrap();
        assert_eq!(report.files_fetched, 1);
        assert_eq!(report.patch_files_fetched, 1);
        assert!(
            report.artifact_bytes >= out3.bytes,
            "wire bytes ({}) must cover the patch body ({})",
            report.artifact_bytes,
            out3.bytes
        );
        assert!(
            report.artifact_bytes < out3.bytes + 2048,
            "wire overhead beyond the patch body must be header-sized ({} vs {})",
            report.artifact_bytes,
            out3.bytes
        );
        assert!(
            report.artifact_bytes < full.bytes * 15 / 100,
            "a one-module patch must replicate in <15% of the consolidated bytes \
             ({} vs {})",
            report.artifact_bytes,
            full.bytes
        );
        assert_eq!(
            logits_of(&base, &leader_dir, "ft", &tokens),
            logits_of(&base, &follower_dir, "ft", &tokens),
        );

        // Leader rollback converges over HTTP with zero artifact bytes.
        leader.rollback("ft", Some(2)).unwrap();
        let report = repl.sync_once(None).unwrap();
        assert_eq!(report.files_fetched, 0);
        assert_eq!(report.artifact_bytes, 0);
        assert_eq!(follower.resolve("ft").unwrap().version, 2);
    })
}

#[test]
fn idle_long_poll_moves_header_bytes_only() {
    with_timeout("idle_long_poll", 60, || {
        let leader_dir = fresh_dir("pawd_itest_http_idle_leader");
        let follower_dir = fresh_dir("pawd_itest_http_idle_follower");
        let cfg = ModelConfig::preset("tiny").unwrap();
        let base = Arc::new(FlatParams::init(&cfg, 109));
        let leader = Arc::new(VariantRegistry::open(&leader_dir).unwrap());
        leader.publish("ft", seeded_full(&base, "ft", 31)).unwrap();
        let frontend =
            HttpFrontend::start("127.0.0.1:0", None, leader.clone(), FrontConfig::default())
                .unwrap();
        let follower = Arc::new(VariantRegistry::open(&follower_dir).unwrap());
        let repl = Replicator::new(
            follower,
            Box::new(HttpTransport::new(&frontend.url()).unwrap()),
        );
        let cold = repl.sync_once(None).unwrap();
        assert!(!cold.up_to_date);
        let polls_before = pawd::exec::counters::http_long_polls();

        // Nothing published: the wait burns its window server-side and the
        // whole pass costs one 304's worth of headers — no manifest body,
        // no artifact bytes.
        let t0 = Instant::now();
        let report = repl.sync_wait(None, Duration::from_millis(400)).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(300), "poll must park server-side");
        assert!(report.up_to_date);
        assert_eq!(report.files_fetched, 0);
        assert_eq!(report.artifact_bytes, 0);
        assert!(
            report.manifest_bytes > 0 && report.manifest_bytes < 600,
            "an idle poll must cost header bytes only, got {}",
            report.manifest_bytes
        );
        assert!(pawd::exec::counters::http_long_polls() > polls_before);
    })
}

#[test]
fn long_poll_wakes_early_on_publish() {
    with_timeout("long_poll_wakes", 60, || {
        let leader_dir = fresh_dir("pawd_itest_http_wake_leader");
        let follower_dir = fresh_dir("pawd_itest_http_wake_follower");
        let cfg = ModelConfig::preset("tiny").unwrap();
        let base = Arc::new(FlatParams::init(&cfg, 113));
        let leader = Arc::new(VariantRegistry::open(&leader_dir).unwrap());
        let v1 = seeded_full(&base, "ft", 41);
        leader.publish_incremental("ft", v1.clone(), None).unwrap();
        let frontend =
            HttpFrontend::start("127.0.0.1:0", None, leader.clone(), FrontConfig::default())
                .unwrap();
        let follower = Arc::new(VariantRegistry::open(&follower_dir).unwrap());
        let repl = Replicator::new(
            follower.clone(),
            Box::new(HttpTransport::new(&frontend.url()).unwrap()),
        );
        repl.sync_once(None).unwrap();

        // Publish from another thread mid-poll: the condvar watch must wake
        // the parked poll long before its 20s window expires.
        let publisher = {
            let leader = leader.clone();
            let base = base.clone();
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(200));
                let v2 = perturb_one(&v1, &base, 1, 143);
                leader.publish_incremental("ft", v2, None).unwrap();
            })
        };
        let t0 = Instant::now();
        let report = repl.sync_wait(None, Duration::from_secs(20)).unwrap();
        let elapsed = t0.elapsed();
        publisher.join().unwrap();
        assert!(!report.up_to_date, "the poll must observe the publish");
        assert_eq!(report.files_fetched, 1);
        assert!(
            elapsed < Duration::from_secs(10),
            "poll must wake on publish, not burn the window (took {elapsed:?})"
        );
        assert_eq!(follower.resolve("ft").unwrap().version, 2);
    })
}

#[test]
fn keep_alive_serves_pipelined_requests_on_one_connection() {
    with_timeout("keep_alive_pipeline", 60, || {
        let dir = fresh_dir("pawd_itest_http_keepalive");
        let registry = Arc::new(VariantRegistry::open(&dir).unwrap());
        let frontend =
            HttpFrontend::start("127.0.0.1:0", None, registry, FrontConfig::default()).unwrap();
        let two = raw_exchange(
            frontend.addr(),
            b"GET /v1/healthz HTTP/1.1\r\nHost: t\r\n\r\n\
              GET /v1/healthz HTTP/1.1\r\nHost: t\r\n\r\n",
        );
        assert_eq!(
            two.matches("HTTP/1.1 200 OK").count(),
            2,
            "both pipelined requests must be served on one connection: {two}"
        );
        assert!(two.contains("Connection: keep-alive"), "first reply keeps the connection");
    })
}

#[test]
fn sync_file_route_serves_ranges_with_whole_file_crc() {
    with_timeout("range_and_crc", 60, || {
        let dir = fresh_dir("pawd_itest_http_range");
        let cfg = ModelConfig::preset("tiny").unwrap();
        let base = Arc::new(FlatParams::init(&cfg, 127));
        let registry = Arc::new(VariantRegistry::open(&dir).unwrap());
        registry.publish("ft", seeded_full(&base, "ft", 51)).unwrap();
        let file = registry.list()[0].versions[0].file.clone();
        let disk = std::fs::read(dir.join(&file)).unwrap();
        let crc = format!("{:08x}", crc32::hash(&disk));
        let frontend =
            HttpFrontend::start("127.0.0.1:0", None, registry, FrontConfig::default()).unwrap();

        let full = raw_exchange(
            frontend.addr(),
            format!("GET /v1/sync/file/{file} HTTP/1.1\r\nHost: t\r\n\r\n").as_bytes(),
        );
        assert!(full.starts_with("HTTP/1.1 200"), "got: {}", &full[..full.len().min(120)]);
        assert!(full.contains(&format!("X-Content-Crc32: {crc}")));
        assert!(full.contains("Accept-Ranges: bytes"));

        // Resume from the middle: 206 with the suffix, Content-Range, and
        // the *whole-file* crc so the client can verify after reassembly.
        let offset = disk.len() / 2;
        let part = raw_exchange(
            frontend.addr(),
            format!(
                "GET /v1/sync/file/{file} HTTP/1.1\r\nHost: t\r\nRange: bytes={offset}-\r\n\r\n"
            )
            .as_bytes(),
        );
        assert!(part.starts_with("HTTP/1.1 206"), "got: {}", &part[..part.len().min(120)]);
        assert!(part.contains(&format!(
            "Content-Range: bytes {offset}-{}/{}",
            disk.len() - 1,
            disk.len()
        )));
        assert!(part.contains(&format!("X-Content-Crc32: {crc}")));
        assert!(part.contains(&format!("Content-Length: {}", disk.len() - offset)));

        // A range past the end is a 416, not a panic or an empty 206.
        let beyond = raw_exchange(
            frontend.addr(),
            format!(
                "GET /v1/sync/file/{file} HTTP/1.1\r\nHost: t\r\nRange: bytes={}-\r\n\r\n",
                disk.len() + 10
            )
            .as_bytes(),
        );
        assert!(beyond.starts_with("HTTP/1.1 416"), "got: {}", &beyond[..beyond.len().min(120)]);
    })
}

#[test]
fn sync_only_frontend_rejects_data_and_admin_planes() {
    with_timeout("sync_only_503", 60, || {
        let dir = fresh_dir("pawd_itest_http_synconly");
        let registry = Arc::new(VariantRegistry::open(&dir).unwrap());
        let frontend =
            HttpFrontend::start("127.0.0.1:0", None, registry, FrontConfig::default()).unwrap();
        let api = HttpApiClient::new(&frontend.url()).unwrap();
        api.health().unwrap();
        let err = api.score("ft", "Q", &["a".into()]).unwrap_err().to_string();
        assert!(err.contains("503"), "data plane must 503 on a sync-only frontend: {err}");
        let err = api.admin(&AdminOp::List).unwrap_err().to_string();
        assert!(err.contains("503"), "admin plane must 503 on a sync-only frontend: {err}");

        // Malformed query bodies are 400s.
        let resp = raw_exchange(
            frontend.addr(),
            b"POST /v1/query HTTP/1.1\r\nHost: t\r\nContent-Length: 9\r\n\r\nnot JSON!",
        );
        assert!(resp.starts_with("HTTP/1.1 503") || resp.starts_with("HTTP/1.1 400"));
    })
}
