// Fixture: seeded A203 — a condvar wait with no enclosing loop, so a
// spurious wakeup returns with the predicate unchecked.

fn wait_once(m: &std::sync::Mutex<bool>, cv: &std::sync::Condvar) {
    let g = m.lock().unwrap();
    let _g = cv.wait(g).unwrap();
}
