// Fixture: seeded A001 — the block opened by `broken` is never closed.

fn broken() {
    if true {
        let _x = 1;
}
