// Fixture: seeded A003 — match over Fruit misses Cherry and has no catch-all.

pub enum Fruit {
    Apple,
    Banana,
    Cherry,
}

pub fn describe(f: &Fruit) -> &'static str {
    match f {
        Fruit::Apple => "apple",
        Fruit::Banana => "banana",
    }
}
