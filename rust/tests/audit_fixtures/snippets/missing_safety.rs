// Fixture: seeded A201 — unsafe block without a justification comment.

fn deref(p: *const u32) -> u32 {
    unsafe { *p }
}
