pub struct Present;
