// Fixture crate: seeded A002 — `exec` only exports `Present`, so the
// `use crate::exec::Missing;` below cannot resolve.

pub mod exec;

use crate::exec::Missing;

pub fn touch() -> Missing {
    Missing
}
