pub struct MetricsSnapshot {
    pub orphan_counter: u64,
}

pub fn snapshot_inner() -> MetricsSnapshot {
    MetricsSnapshot { orphan_counter: crate::exec::counters::orphan_counter() }
}
