//! Fixture counters registry. `orphan_counter` is wired through the
//! snapshot, the wire codec, and the serve summary — but deliberately
//! missing from the README counter table (seeded A101).

pub fn orphan_counter() -> u64 {
    0
}

pub fn reset() {}
