fn main() {
    let snap = crate::coordinator::metrics::snapshot_inner();
    println!("{}", snap.orphan_counter);
}
