pub fn snapshot_to_json() -> &'static str {
    "orphan_counter"
}

pub fn snapshot_from_json() -> &'static str {
    "orphan_counter"
}
