//! Batched multi-variant execution: the shared-base `BatchPlan` path must
//! be *bitwise* equal to the per-request fused path, from the exec layer up
//! through the serving coordinator's mixed batch windows.

use pawd::coordinator::{Engine, Payload, RespBody, Server, ServerConfig, VariantStore};
use pawd::delta::compress::{compress_model, CompressOptions, FitMode};
use pawd::delta::format::save_delta;
use pawd::exec::{BatchPlan, ExecMode, VariantWeights};
use pawd::model::config::ModelConfig;
use pawd::model::synth::{synth_finetune, SynthDeltaSpec};
use pawd::model::{FlatParams, Transformer};
use pawd::util::rng::Rng;
use std::path::PathBuf;
use std::sync::Arc;

fn setup_store(dir: &PathBuf, n_variants: usize) -> (Arc<FlatParams>, VariantStore) {
    let _ = std::fs::remove_dir_all(dir);
    std::fs::create_dir_all(dir).unwrap();
    let cfg = ModelConfig::preset("tiny").unwrap();
    let base = Arc::new(FlatParams::init(&cfg, 123));
    let docs: Vec<Vec<u8>> = (0..3)
        .map(|i| (0..40).map(|t| ((t * 5 + i * 11) % 200 + 20) as u8).collect())
        .collect();
    let opts = CompressOptions { fit: FitMode::ClosedForm, ..Default::default() };
    for k in 0..n_variants {
        let ft = synth_finetune(
            &base,
            &SynthDeltaSpec { seed: 4000 + k as u64, ..Default::default() },
        );
        let (delta, _, _) = compress_model(&format!("var{k}"), &base, &ft, &docs, &opts);
        save_delta(dir.join(format!("var{k}.pawd")), &delta).unwrap();
    }
    let store = VariantStore::new(base.clone(), dir).with_mode(ExecMode::Fused);
    (base, store)
}

/// Property: over random mixed batches (variant assignment, sequence count
/// and lengths), a `BatchPlan` forward is bitwise-equal to running every
/// sequence through the per-request `FusedDeltaLinear` path.
#[test]
fn prop_mixed_batch_plan_forward_is_bitwise_equal_to_per_request() {
    let dir = std::env::temp_dir().join("pawd_itest_batched_prop");
    let (base, store) = setup_store(&dir, 3);
    let tf = Transformer::new(base.cfg());
    let weights: Vec<VariantWeights> =
        (0..3).map(|k| store.load(&format!("var{k}")).unwrap().weights).collect();
    assert!(weights.iter().all(|w| w.is_packed()));

    let mut rng = Rng::new(777);
    for case in 0..12 {
        let n_seqs = 1 + rng.below(6);
        let batch_weights: Vec<VariantWeights> =
            (0..n_seqs).map(|_| weights[rng.below(3)].clone()).collect();
        let plans = BatchPlan::group(&batch_weights);
        assert_eq!(plans.len(), 1, "packed variants of one base share one plan");
        let (plan, members) = &plans[0];
        let seqs: Vec<(usize, Vec<u8>)> = (0..n_seqs)
            .map(|entry| {
                let len = 1 + rng.below(base.cfg().max_seq);
                (entry, (0..len).map(|_| rng.below(256) as u8).collect())
            })
            .collect();
        let batched = tf.forward_plan(plan, &seqs);
        for ((entry, tokens), got) in seqs.iter().zip(&batched) {
            let want = tf.forward_one(&batch_weights[members[*entry]], tokens);
            assert_eq!(
                got.data.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                want.data.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "case {case}: batched forward diverged from the per-request path"
            );
        }
    }
}

/// The serving coordinator forms mixed-variant windows under concurrent
/// load and its batched scores must equal the direct per-request
/// computation exactly.
#[test]
fn server_mixed_windows_score_identically_to_direct_eval() {
    let dir = std::env::temp_dir().join("pawd_itest_batched_serve");
    let (base, store) = setup_store(&dir, 3);
    let tf = Transformer::new(base.cfg());
    // Direct per-request ground truth against the same packed weights.
    let direct_store = VariantStore::new(base.clone(), &dir).with_mode(ExecMode::Fused);
    let direct: Vec<VariantWeights> =
        (0..3).map(|k| direct_store.load(&format!("var{k}")).unwrap().weights).collect();

    let server = Server::start(
        store,
        Engine::Native,
        ServerConfig { max_batch: 6, ..Default::default() },
    );
    // Burst concurrent requests across all three variants so the dispatcher
    // coalesces mixed windows.
    let client = server.client();
    let items: Vec<(usize, String, Vec<String>)> = (0..18)
        .map(|i| {
            (
                i % 3,
                format!("Q: mixed batch item {i}? A: "),
                vec!["alpha".to_string(), "beta".to_string(), "gamma".to_string()],
            )
        })
        .collect();
    let rxs: Vec<_> = items
        .iter()
        .map(|(k, prompt, choices)| {
            (client.submit(&format!("var{k}"), Payload::score(prompt, choices)), k, prompt, choices)
        })
        .collect();
    for (rx, k, prompt, choices) in rxs {
        let resp = rx.recv().unwrap();
        assert_eq!(resp.version, Some(1));
        let scores = match resp.result {
            Ok(RespBody::Score { scores, .. }) => scores,
            other => panic!("unexpected {other:?}"),
        };
        // Recompute through the per-request path: same encode/clamp/span
        // logic as the server, then bitwise-identical forwards.
        for (choice, got) in choices.iter().zip(&scores) {
            let full = pawd::data::corpus::encode(&format!("{prompt}{choice}"));
            assert!(full.len() <= tf.cfg.max_seq, "test item unexpectedly clamped");
            let choice_len =
                pawd::data::corpus::encode(choice).len().min(full.len() - 1).max(1);
            let start = full.len() - choice_len;
            let want = tf.score_span(&direct[*k], &full, start..full.len()) / choice_len as f64;
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "batched server score diverged from direct eval: {got} vs {want}"
            );
        }
    }
    let snap = server.metrics.snapshot();
    assert_eq!(snap.served, 18);
    assert_eq!(snap.errors, 0);
    assert!(
        snap.mean_batch_size > 1.0,
        "burst must coalesce into windows, got {}",
        snap.mean_batch_size
    );
    server.shutdown();
}

/// Perplexity requests ride the same batched path.
#[test]
fn server_batched_perplexity_matches_direct() {
    let dir = std::env::temp_dir().join("pawd_itest_batched_ppl");
    let (base, store) = setup_store(&dir, 2);
    let tf = Transformer::new(base.cfg());
    let direct_store = VariantStore::new(base.clone(), &dir).with_mode(ExecMode::Fused);
    let w0 = direct_store.load("var0").unwrap().weights;

    let server = Server::start(store, Engine::Native, ServerConfig::default());
    let client = server.client();
    let text = "the mill by the river turns all day.";
    let rx = client.submit("var0", Payload::perplexity(text));
    let got = match rx.recv().unwrap().result {
        Ok(RespBody::Perplexity { nats_per_token }) => nats_per_token,
        other => panic!("unexpected {other:?}"),
    };
    let tokens = pawd::data::corpus::encode(text);
    let want = tf.cross_entropy(&w0, &tokens);
    assert_eq!(got.to_bits(), want.to_bits(), "{got} vs {want}");
    // Degenerate input still errors per-request, not per-window.
    let rx = client.submit("var1", Payload::perplexity("x"));
    assert!(rx.recv().unwrap().result.is_err());
    server.shutdown();
}
