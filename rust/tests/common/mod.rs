//! Shared helpers for the integration tests. Each test target pulls this in
//! with `mod common;` (cargo only builds top-level `tests/*.rs` as targets,
//! so this directory is plain shared code, like `benches/bench_common`).

#![allow(dead_code)]

use pawd::delta::pack::PackedMask;
use pawd::delta::types::{Axis, Codec, CodecKind, DeltaModel, DeltaModule, LowRank};
use pawd::model::FlatParams;
use pawd::util::rng::Rng;
use std::path::PathBuf;

/// Run `f` on its own thread and fail hard if it exceeds `secs` — network
/// tests must fail loudly instead of wedging the whole suite when a socket
/// or long-poll misbehaves. Panics from `f` propagate unchanged.
pub fn with_timeout<T: Send + 'static>(
    name: &str,
    secs: u64,
    f: impl FnOnce() -> T + Send + 'static,
) -> T {
    let (tx, rx) = std::sync::mpsc::channel();
    let handle = std::thread::Builder::new()
        .name(name.to_string())
        .spawn(move || {
            let _ = tx.send(f());
        })
        .unwrap();
    match rx.recv_timeout(std::time::Duration::from_secs(secs)) {
        Ok(v) => {
            let _ = handle.join();
            v
        }
        Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => match handle.join() {
            Err(panic) => std::panic::resume_unwind(panic),
            Ok(()) => panic!("test '{name}' worker exited without a result"),
        },
        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
            panic!("test '{name}' exceeded its {secs}s hard timeout")
        }
    }
}

pub fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A full delta over every patchable module of `base`, content seeded.
/// `axes` rotates per (seed, module): pass a single axis for deterministic
/// single-axis layouts (replication tests) or several for mixed-axis
/// coverage (chain tests).
pub fn seeded_full(base: &FlatParams, variant: &str, seed: u64, axes: &[Axis]) -> DeltaModel {
    let cfg = base.cfg();
    let modules: Vec<DeltaModule> = base
        .layout
        .patchable_modules()
        .iter()
        .enumerate()
        .map(|(i, &id)| {
            let (rows, cols) = id.kind.shape(cfg);
            let mut r = Rng::new(seed.wrapping_mul(613).wrapping_add(i as u64));
            let delta: Vec<f32> = (0..rows * cols).map(|_| r.normal_f32(0.0, 1.0)).collect();
            let axis = axes[(seed as usize + i) % axes.len()];
            DeltaModule {
                id,
                mask: PackedMask::pack(&delta, rows, cols),
                axis,
                scales: (0..axis.n_scales(rows, cols))
                    .map(|_| r.uniform_in(0.005, 0.05))
                    .collect(),
                codec: Codec::PerAxis,
            }
        })
        .collect();
    DeltaModel::new(variant, cfg.name.clone(), modules)
}

/// A full delta cycling through every codec kind per module (per-axis,
/// scalar, low-rank), content seeded — the mixed-codec artifact the format
/// v4 / replication round-trip tests exercise.
pub fn seeded_full_mixed(base: &FlatParams, variant: &str, seed: u64) -> DeltaModel {
    let cfg = base.cfg();
    let modules: Vec<DeltaModule> = base
        .layout
        .patchable_modules()
        .iter()
        .enumerate()
        .map(|(i, &id)| {
            let (rows, cols) = id.kind.shape(cfg);
            let mut r = Rng::new(seed.wrapping_mul(917).wrapping_add(i as u64));
            let delta: Vec<f32> = (0..rows * cols).map(|_| r.normal_f32(0.0, 1.0)).collect();
            let kind = CodecKind::ALL[i % CodecKind::ALL.len()];
            let axis = if kind == CodecKind::Scalar { Axis::Scalar } else { Axis::Row };
            let codec = match kind {
                CodecKind::PerAxis => Codec::PerAxis,
                CodecKind::Scalar => Codec::Scalar,
                CodecKind::LowRank => {
                    let rank = 2.min(rows).min(cols);
                    Codec::LowRank(LowRank {
                        rank,
                        a: (0..rank * cols).map(|_| r.normal_f32(0.0, 0.02)).collect(),
                        b: (0..rows * rank).map(|_| r.normal_f32(0.0, 0.02)).collect(),
                    })
                }
            };
            DeltaModule {
                id,
                mask: PackedMask::pack(&delta, rows, cols),
                axis,
                scales: (0..axis.n_scales(rows, cols))
                    .map(|_| r.uniform_in(0.005, 0.05))
                    .collect(),
                codec,
            }
        })
        .collect();
    DeltaModel::new(variant, cfg.name.clone(), modules)
}
