//! Incremental-publish integration: chain-composed loads are bitwise-equal
//! to consolidated full artifacts (packed bytes AND eval logits), patch
//! warming composes from the resident parent, and pre-v3 artifacts still
//! serve through the v3 reader.

mod common;

use common::fresh_dir;
use pawd::coordinator::{VariantCache, VariantRegistry, VariantStore};
use pawd::delta::format::{load_delta, save_delta_v2_bytes};
use pawd::delta::types::{ArtifactMeta, Axis, DeltaModel};
use pawd::exec::{ExecMode, PackedVariant, VariantWeights};
use pawd::model::config::ModelConfig;
use pawd::model::{FlatParams, Transformer};
use pawd::util::f16::encode_f16_slice;
use pawd::util::prop::check;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Mixed-axis seeded delta (axis coverage across Row/Col/Scalar/Group).
fn seeded_full(base: &FlatParams, seed: u64) -> DeltaModel {
    let axes = [Axis::Row, Axis::Col, Axis::Scalar, Axis::Group(3)];
    common::seeded_full(base, "ft", seed, &axes)
}

fn assert_packed_bytes_eq(a: &DeltaModel, b: &DeltaModel, ctx: &str) -> Result<(), String> {
    if a.modules.len() != b.modules.len() {
        return Err(format!("{ctx}: module count {} vs {}", a.modules.len(), b.modules.len()));
    }
    for (x, y) in a.modules.iter().zip(&b.modules) {
        if x.id != y.id || x.axis != y.axis {
            return Err(format!("{ctx}: module header mismatch at {}", x.id));
        }
        if x.mask != y.mask {
            return Err(format!("{ctx}: mask bytes differ at {}", x.id));
        }
        if encode_f16_slice(&x.scales) != encode_f16_slice(&y.scales) {
            return Err(format!("{ctx}: scale bits differ at {}", x.id));
        }
    }
    Ok(())
}

#[test]
fn prop_chain_composed_load_is_bitwise_equal_to_consolidated_artifact() {
    let case = AtomicU64::new(0);
    let cfg = ModelConfig::preset("tiny").unwrap();
    let tf = Transformer::new(&cfg);
    check("chain-vs-consolidated", 8, 8, |g| {
        let dir = fresh_dir(&format!(
            "pawd_prop_chain_{}",
            case.fetch_add(1, Ordering::Relaxed)
        ));
        let base = Arc::new(FlatParams::init(&cfg, 7 + g.size as u64));
        let registry = VariantRegistry::open(&dir).map_err(|e| e.to_string())?;
        // v1: full publish.
        let mut effective = seeded_full(&base, 1000 + g.size as u64);
        registry
            .publish_incremental("ft", effective.clone(), None)
            .map_err(|e| e.to_string())?;
        // 1..=3 patch steps, each changing a random non-empty module subset.
        let steps = 1 + g.rng.below(3);
        let mut final_version = 1;
        for step in 0..steps {
            let n = effective.modules.len();
            let n_changed = 1 + g.rng.below(n.min(4));
            let fresh = seeded_full(&base, 5000 + step as u64 * 97 + g.size as u64);
            for _ in 0..n_changed {
                let k = g.rng.below(n);
                effective.modules[k] = fresh.modules[k].clone();
            }
            let out = registry
                .publish_incremental("ft", effective.clone(), None)
                .map_err(|e| e.to_string())?;
            if !out.patch {
                return Err(format!("step {step}: expected a patch publish"));
            }
            final_version = out.version;
        }
        // Chain-composed load (cold, straight from disk).
        let composed = registry
            .effective_model("ft", final_version)
            .map_err(|e| e.to_string())?;
        // Consolidate in place, reload the now-full artifact.
        let c = registry.consolidate("ft", Some(final_version)).map_err(|e| e.to_string())?;
        if c.rebased_links < 2 {
            return Err("consolidation should have rebased a multi-link chain".into());
        }
        let resolved = registry
            .resolve(&format!("ft@{final_version}"))
            .map_err(|e| e.to_string())?;
        if resolved.patch {
            return Err("consolidated version must resolve as full".into());
        }
        let full = load_delta(&resolved.path).map_err(|e| e.to_string())?;
        // Packed bytes: bitwise identical.
        assert_packed_bytes_eq(&composed, &full, "composed vs consolidated")?;
        // Eval logits: bitwise identical forwards through the fused path.
        let pv_a = PackedVariant::new(base.clone(), Arc::new(composed)).map_err(|e| e.to_string())?;
        let pv_b = PackedVariant::new(base.clone(), Arc::new(full)).map_err(|e| e.to_string())?;
        let tokens: Vec<u8> =
            (0..10u8).map(|t| t.wrapping_mul(23).wrapping_add(g.size as u8) % 200 + 10).collect();
        let la = tf.forward_one(&pv_a, &tokens);
        let lb = tf.forward_one(&pv_b, &tokens);
        for (x, y) in la.data.iter().zip(&lb.data) {
            if x.to_bits() != y.to_bits() {
                return Err("eval logits differ between composed and consolidated".into());
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
        Ok(())
    });
}

#[test]
fn patch_warming_inherits_resident_parent_modules() {
    let dir = fresh_dir("pawd_itest_chain_warm");
    let cfg = ModelConfig::preset("tiny").unwrap();
    let base = Arc::new(FlatParams::init(&cfg, 3));
    let store = VariantStore::new(base.clone(), &dir).with_mode(ExecMode::Fused);
    let registry = store.registry().clone();
    let v1 = seeded_full(&base, 42);
    registry.publish_incremental("ft", v1, None).unwrap();
    let cache = VariantCache::new(store, u64::MAX);
    let (w1, _) = cache.get("ft").unwrap();
    // Publish v2 changing one module.
    let mut v2 = registry.effective_model("ft", 1).unwrap();
    {
        let m = Arc::make_mut(&mut v2.modules[3]);
        for s in &mut m.scales {
            *s *= 2.0;
        }
    }
    let out = registry.publish_incremental("ft", v2, None).unwrap();
    assert!(out.patch);
    let (w2, cold) = cache.get("ft").unwrap();
    assert!(cold.is_some());
    let (a, b) = match (&w1, &w2) {
        (VariantWeights::Packed(a), VariantWeights::Packed(b)) => (a, b),
        _ => panic!("expected packed weights"),
    };
    // All but the changed module are the parent's own Arcs: warming read
    // only the patch.
    let shared = b
        .module_arcs()
        .iter()
        .filter(|m| a.module_arcs().iter().any(|p| Arc::ptr_eq(p, m)))
        .count();
    assert_eq!(shared, b.module_arcs().len() - 1);
    // Both serve: spot-check a forward through each.
    let tf = Transformer::new(&cfg);
    let tokens: Vec<u8> = vec![5, 9, 13, 17, 21];
    let l1 = tf.forward_one(&w1, &tokens);
    let l2 = tf.forward_one(&w2, &tokens);
    assert_ne!(
        l1.data.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        l2.data.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        "the changed module must change the logits"
    );
}

#[test]
fn v2_artifacts_serve_through_the_v3_stack() {
    let dir = fresh_dir("pawd_itest_v2compat");
    let cfg = ModelConfig::preset("tiny").unwrap();
    let base = Arc::new(FlatParams::init(&cfg, 5));
    let mut model = seeded_full(&base, 77);
    model.variant = "legacy2".into();
    model.meta = ArtifactMeta { version: 4, parent: Some(3), created_unix: 123, is_patch: false };
    std::fs::write(dir.join("legacy2.pawd"), save_delta_v2_bytes(&model)).unwrap();

    let store = VariantStore::new(base.clone(), &dir).with_mode(ExecMode::Fused);
    let loaded = store.load("legacy2").unwrap();
    assert_eq!(loaded.version, 4, "adoption honors the v2 embedded version");
    assert!(loaded.weights.is_packed());
    // Content survives the v2 reader bit-for-bit.
    match &loaded.weights {
        VariantWeights::Packed(pv) => {
            assert_packed_bytes_eq(pv.delta().as_ref(), &model, "v2 through stack").unwrap();
        }
        _ => panic!("expected packed"),
    }
    // And a consolidation no-op doesn't disturb it.
    let out = store.registry().consolidate("legacy2", None).unwrap();
    assert_eq!((out.version, out.rebased_links), (4, 0));
    assert!(store.load("legacy2").is_ok());
}
