//! Continuous-batching engine + intra-host compute pool, end to end:
//! pooled forwards must be *bitwise* equal to serial ones at any thread
//! width, the engine must keep admitting mid-flight requests during a
//! publish storm without ever serving a stale alias, and an idle host must
//! answer a lone request immediately (no dispatch-deadline stall).

use pawd::coordinator::{Engine, Payload, RespBody, Server, ServerConfig, VariantStore};
use pawd::delta::compress::{compress_model, CompressOptions, FitMode};
use pawd::delta::format::save_delta;
use pawd::exec::{pool, BatchPlan, ExecMode, VariantWeights};
use pawd::model::config::ModelConfig;
use pawd::model::synth::{synth_finetune, SynthDeltaSpec};
use pawd::model::{FlatParams, Transformer};
use pawd::util::rng::Rng;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn setup_store(dir: &PathBuf, n_variants: usize) -> (Arc<FlatParams>, VariantStore) {
    let _ = std::fs::remove_dir_all(dir);
    std::fs::create_dir_all(dir).unwrap();
    let cfg = ModelConfig::preset("tiny").unwrap();
    let base = Arc::new(FlatParams::init(&cfg, 123));
    let docs: Vec<Vec<u8>> = (0..3)
        .map(|i| (0..40).map(|t| ((t * 5 + i * 11) % 200 + 20) as u8).collect())
        .collect();
    let opts = CompressOptions { fit: FitMode::ClosedForm, ..Default::default() };
    for k in 0..n_variants {
        let ft = synth_finetune(
            &base,
            &SynthDeltaSpec { seed: 6000 + k as u64, ..Default::default() },
        );
        let (delta, _, _) = compress_model(&format!("var{k}"), &base, &ft, &docs, &opts);
        save_delta(dir.join(format!("var{k}.pawd")), &delta).unwrap();
    }
    let store = VariantStore::new(base.clone(), dir).with_mode(ExecMode::Fused);
    (base, store)
}

/// Property: the pooled compute path (4 threads) produces bitwise-identical
/// logits to the serial path (1 thread) for both the per-request forward
/// and the shared-base `BatchPlan` forward, over random mixed batches.
/// Parallelism splits work across output rows and sequences, never inside
/// one floating-point reduction, so this must hold exactly.
#[test]
fn prop_pooled_forward_is_bitwise_equal_to_serial() {
    let dir = std::env::temp_dir().join("pawd_itest_pool_bitwise");
    let (base, store) = setup_store(&dir, 3);
    let tf = Transformer::new(base.cfg());
    let weights: Vec<VariantWeights> =
        (0..3).map(|k| store.load(&format!("var{k}")).unwrap().weights).collect();

    let mut rng = Rng::new(991);
    for case in 0..8 {
        let n_seqs = 1 + rng.below(5);
        let batch_weights: Vec<VariantWeights> =
            (0..n_seqs).map(|_| weights[rng.below(3)].clone()).collect();
        let plans = BatchPlan::group(&batch_weights);
        let (plan, _) = &plans[0];
        let seqs: Vec<(usize, Vec<u8>)> = (0..n_seqs)
            .map(|entry| {
                let len = 1 + rng.below(base.cfg().max_seq);
                (entry, (0..len).map(|_| rng.below(256) as u8).collect())
            })
            .collect();
        let serial = pool::with_thread_limit(1, || tf.forward_plan(plan, &seqs));
        let pooled = pool::with_thread_limit(4, || tf.forward_plan(plan, &seqs));
        for (s, p) in serial.iter().zip(&pooled) {
            assert_eq!(
                s.data.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                p.data.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "case {case}: pooled forward_plan diverged from serial"
            );
        }
        // The per-request path fans out the same way.
        let (_, tokens) = &seqs[0];
        let s1 = pool::with_thread_limit(1, || tf.forward_one(&batch_weights[0], tokens));
        let s4 = pool::with_thread_limit(4, || tf.forward_one(&batch_weights[0], tokens));
        assert_eq!(
            s1.data.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            s4.data.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "case {case}: pooled forward_one diverged from serial"
        );
    }
}

/// The engine keeps admitting data requests *while* a `publish_incremental`
/// storm rides the admin fast lane, and after each publish returns the new
/// alias is immediately live: a fresh score never sees a stale version.
#[test]
fn engine_admits_during_publish_storm_without_serving_stale_alias() {
    let dir = std::env::temp_dir().join("pawd_itest_publish_storm");
    let (base, store) = setup_store(&dir, 2);
    let staging = std::env::temp_dir().join("pawd_itest_publish_storm_staging");
    let _ = std::fs::remove_dir_all(&staging);
    std::fs::create_dir_all(&staging).unwrap();

    let server = Server::start(
        store,
        Engine::Native,
        ServerConfig { n_workers: 2, ..Default::default() },
    );
    let stop = AtomicBool::new(false);
    let background_ok = AtomicU64::new(0);
    std::thread::scope(|s| {
        // Background traffic on a *stable* variant must keep flowing
        // error-free through the storm (publishes overlap with serving
        // instead of stalling it).
        let bg = server.client();
        let (stop_ref, ok_ref) = (&stop, &background_ok);
        s.spawn(move || {
            let mut i = 0u64;
            while !stop_ref.load(Ordering::Relaxed) {
                let resp = bg.score(
                    "var1",
                    &format!("Q: steady {i}? A: "),
                    &["yes".to_string(), "no".to_string()],
                );
                assert!(resp.result.is_ok(), "background request failed: {:?}", resp.result);
                ok_ref.fetch_add(1, Ordering::Relaxed);
                i += 1;
            }
        });

        let admin = server.client();
        // Warm v1 so each incremental publish diffs a resident parent.
        let r1 = admin.score("var0", "Q: warm? A: ", &["x".to_string(), "y".to_string()]);
        assert_eq!(r1.version, Some(1));
        // Storm: publish a chain of single-module changes; after each one
        // returns, the very next score must serve the new version.
        let mut model = pawd::delta::format::load_delta(dir.join("var0.pawd")).unwrap();
        for step in 0..5u32 {
            {
                let m = Arc::make_mut(&mut model.modules[0]);
                for sc in &mut m.scales {
                    *sc *= 1.25;
                }
            }
            let staged = staging.join(format!("v{}.pawd", step + 2));
            save_delta(&staged, &model).unwrap();
            let (version, _, _) = admin.publish_incremental("var0", &staged, None).unwrap();
            assert_eq!(version, step + 2);
            let probe =
                admin.score("var0", "Q: fresh? A: ", &["x".to_string(), "y".to_string()]);
            assert!(probe.result.is_ok());
            assert_eq!(
                probe.version,
                Some(version),
                "score submitted after publish v{version} served a stale alias"
            );
        }
        stop.store(true, Ordering::Relaxed);
    });
    assert!(background_ok.load(Ordering::Relaxed) > 0, "no background traffic during storm");
    let snap = server.metrics.snapshot();
    assert_eq!(snap.errors, 0, "publish storm must not fail data requests");
    assert_eq!(snap.publishes, 5);
    assert!(snap.engine_steps > 0, "data windows must flow through engine steps");
    server.shutdown();
}

/// Regression for the dispatcher idle-latency bug: the old loop held a
/// window open up to a dispatch deadline even with every worker idle. The
/// engine flushes on idle capacity (there is no deadline knob anymore), so
/// a lone request must complete at compute latency.
#[test]
fn lone_request_on_idle_host_dispatches_immediately() {
    let dir = std::env::temp_dir().join("pawd_itest_idle_latency");
    let (_base, store) = setup_store(&dir, 1);
    let server = Server::start(store, Engine::Native, ServerConfig::default());
    let client = server.client();
    // Warm the variant so the timed request measures dispatch + compute,
    // not artifact load.
    let warm = client.score("var0", "Q: warm? A: ", &["x".to_string(), "y".to_string()]);
    assert!(warm.result.is_ok());
    let start = Instant::now();
    let rx = client.submit("var0", Payload::perplexity("the mill by the river turns."));
    let resp = rx.recv().unwrap();
    let elapsed = start.elapsed();
    assert!(matches!(resp.result, Ok(RespBody::Perplexity { .. })), "{:?}", resp.result);
    assert!(
        elapsed < Duration::from_secs(1),
        "idle host held a lone request for {elapsed:?} (deadline-wait leak)"
    );
    // The queue stage itself must be far under the deadline too.
    assert!(
        resp.timing.queue < Duration::from_millis(500),
        "queue stage {:?} looks like a deadline wait",
        resp.timing.queue
    );
    server.shutdown();
}

/// `submit_tracked` + `abort`: a request aborted while the queue is
/// saturated answers with an error instead of executing; unknown ids and
/// already-completed requests are no-ops.
#[test]
fn abort_drops_pending_requests_and_ignores_unknown_ids() {
    let dir = std::env::temp_dir().join("pawd_itest_abort");
    let (_base, store) = setup_store(&dir, 1);
    // One worker and tiny windows so a burst keeps requests pending long
    // enough to abort some.
    let server = Server::start(
        store,
        Engine::Native,
        ServerConfig { n_workers: 1, max_batch: 1, ..Default::default() },
    );
    let client = server.client();
    let warm = client.score("var0", "Q: warm? A: ", &["x".to_string(), "y".to_string()]);
    assert!(warm.result.is_ok());
    let submitted: Vec<(u64, std::sync::mpsc::Receiver<pawd::coordinator::Response>)> = (0..12)
        .map(|i| {
            client.submit_tracked("var0", Payload::perplexity(&format!("probe text {i} runs on")))
        })
        .collect();
    // Abort the tail of the queue while the head is executing.
    for (id, _) in submitted.iter().rev().take(6) {
        client.abort(*id);
    }
    client.abort(u64::MAX); // unknown id: no-op
    let mut aborted = 0;
    let mut served = 0;
    for (_, rx) in submitted {
        let resp = rx.recv().unwrap();
        match resp.result {
            Err(e) if e.contains("aborted") => aborted += 1,
            Ok(_) => served += 1,
            Err(e) => panic!("unexpected failure: {e}"),
        }
    }
    assert_eq!(aborted + served, 12);
    assert!(served >= 6, "aborts must never cancel admitted work");
    assert!(aborted >= 1, "tail aborts should catch still-pending requests");
    server.shutdown();
}
