//! Property-based invariants over the delta machinery, driven by the
//! in-repo seeded shrinking harness (`util::prop`; `proptest` is not
//! available offline — see DESIGN.md).

use pawd::delta::calibrate::{
    closed_form_col, closed_form_rowfam, col_stats, mse_col, mse_rowfam, residual, row_stats,
};
use pawd::delta::pack::PackedMask;
use pawd::delta::types::{Axis, Codec, DeltaModule};
use pawd::exec::{FusedDeltaLinear, LinearOp};
use pawd::model::{ModuleId, ProjKind};
use pawd::tensor::Tensor2;
use pawd::util::prop::{assert_close, check, Gen};

fn rand_tensor(g: &mut Gen, rows: usize, cols: usize) -> Tensor2 {
    Tensor2::from_vec(rows, cols, g.vec_normal(rows * cols, 1.0))
}

#[test]
fn prop_pack_roundtrip_preserves_signs() {
    check("pack-roundtrip", 60, 70, |g| {
        let d_out = g.dim();
        let d_in = g.dim();
        let delta = g.vec_nasty(d_out * d_in);
        let m = PackedMask::pack(&delta, d_out, d_in);
        let dense = m.unpack();
        for (i, (&d, &s)) in delta.iter().zip(&dense).enumerate() {
            let want = if d >= 0.0 || d.is_nan() { 1.0 } else { -1.0 };
            if s != want {
                return Err(format!("idx {i}: delta {d} sign {s}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_apply_then_revert_is_identity() {
    check("apply-revert", 40, 50, |g| {
        let d_out = g.dim();
        let d_in = g.dim();
        let base = g.vec_normal(d_out * d_in, 1.0);
        let delta = g.vec_normal(d_out * d_in, 0.2);
        let mask = PackedMask::pack(&delta, d_out, d_in);
        let axis = *g.rng.choice(&[Axis::Row, Axis::Col, Axis::Scalar, Axis::Group(3)]);
        let scales = g.vec_normal(axis.n_scales(d_out, d_in), 0.1);
        let m = DeltaModule {
            id: ModuleId { layer: 0, kind: ProjKind::Q },
            mask,
            axis,
            scales,
            codec: Codec::PerAxis,
        };
        let mut w = base.clone();
        pawd::delta::apply::apply_module_inplace(&mut w, &m, false);
        pawd::delta::apply::apply_module_inplace(&mut w, &m, true);
        assert_close(&w, &base, 1e-5, 1e-5)
    });
}

#[test]
fn prop_apply_optimized_matches_reference() {
    check("apply-vs-reference", 40, 60, |g| {
        let d_out = g.dim();
        let d_in = g.dim();
        let base = g.vec_normal(d_out * d_in, 1.0);
        let delta = g.vec_nasty(d_out * d_in);
        let mask = PackedMask::pack(&delta, d_out, d_in);
        let axis = *g.rng.choice(&[Axis::Row, Axis::Col, Axis::Scalar, Axis::Group(5)]);
        let scales = g.vec_normal(axis.n_scales(d_out, d_in), 0.3);
        let m = DeltaModule {
            id: ModuleId { layer: 0, kind: ProjKind::V },
            mask,
            axis,
            scales,
            codec: Codec::PerAxis,
        };
        let want = pawd::delta::apply::apply_module_reference(&base, &m);
        let mut got = vec![0f32; base.len()];
        pawd::delta::apply::apply_module_into(&base, &mut got, &m);
        assert_close(&got, &want, 0.0, 0.0)
    });
}

#[test]
fn prop_fused_linear_matches_materialized_gemm() {
    // The exec-layer invariant behind the packed-resident serving path:
    // FusedDeltaLinear (never materializes Ŵ) must agree with
    // materialize-then-GEMM within f32 accumulation noise, across all four
    // axis modes and shapes where d_in is not a multiple of the 32-bit mask
    // word (the size generator sweeps 1..=60).
    check("fused-vs-materialized-gemm", 40, 60, |g| {
        let d_out = g.dim();
        let d_in = g.dim();
        let n = 1 + g.rng.below(5);
        let base = g.vec_normal(d_out * d_in, 1.0);
        let delta = g.vec_normal(d_out * d_in, 0.2);
        let mask = PackedMask::pack(&delta, d_out, d_in);
        let axis = *g.rng.choice(&[Axis::Row, Axis::Col, Axis::Scalar, Axis::Group(3)]);
        let scales = g.vec_normal(axis.n_scales(d_out, d_in), 0.3);
        let m = DeltaModule {
            id: ModuleId { layer: 0, kind: ProjKind::O },
            mask,
            axis,
            scales,
            codec: Codec::PerAxis,
        };
        // Reference: dense Ŵ = W_b + v ⊙ B, then a plain GEMM.
        let mut dense = vec![0f32; base.len()];
        pawd::delta::apply::apply_module_into(&base, &mut dense, &m);
        let x = rand_tensor(g, n, d_in);
        let want = x.matmul_bt(&Tensor2::from_vec(d_out, d_in, dense));
        let got = FusedDeltaLinear::new(&base, &m).forward(&x);
        assert_close(&got.data, &want.data, 1e-5, 1e-5)
    });
}

#[test]
fn prop_closed_form_row_is_global_min() {
    check("rowfit-global-min", 25, 24, |g| {
        let d_out = g.dim_at_least(2);
        let d_in = g.dim_at_least(2);
        let n = 4 * (d_in + d_out);
        let x = rand_tensor(g, n, d_in);
        let y = rand_tensor(g, n, d_out);
        let wb = rand_tensor(g, d_out, d_in);
        let delta = g.vec_normal(d_out * d_in, 0.2);
        let mask = PackedMask::pack(&delta, d_out, d_in);
        let r = residual(&x, &y, &wb);
        let st = row_stats(&x, &r, &mask);
        let v = closed_form_rowfam(&st, Axis::Row);
        let best = mse_rowfam(&st, Axis::Row, &v);
        for _ in 0..5 {
            let vp: Vec<f32> = v.iter().map(|&x| x + g.rng.normal_f32(0.0, 0.05)).collect();
            let m = mse_rowfam(&st, Axis::Row, &vp);
            if m < best - 1e-7 {
                return Err(format!("perturbation improved: {m} < {best}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_col_closed_form_is_global_min() {
    check("colfit-global-min", 15, 14, |g| {
        let d_out = g.dim_at_least(2);
        let d_in = g.dim_at_least(2);
        let n = 4 * (d_in + d_out);
        let x = rand_tensor(g, n, d_in);
        let y = rand_tensor(g, n, d_out);
        let wb = rand_tensor(g, d_out, d_in);
        let delta = g.vec_normal(d_out * d_in, 0.2);
        let mask = PackedMask::pack(&delta, d_out, d_in);
        let r = residual(&x, &y, &wb);
        let st = col_stats(&x, &r, &mask);
        let v = closed_form_col(&st, 1e-8);
        let best = mse_col(&st, &v);
        for _ in 0..5 {
            let vp: Vec<f32> = v.iter().map(|&x| x + g.rng.normal_f32(0.0, 0.05)).collect();
            let m = mse_col(&st, &vp);
            if m < best - 1e-6 {
                return Err(format!("perturbation improved: {m} < {best}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_scale_family_nesting() {
    // Row ⊇ Group(g) ⊇ Scalar as function classes: optimal MSE must be
    // monotone in that order for the SAME statistics.
    check("scale-family-nesting", 25, 20, |g| {
        let d_out = 2 * g.dim_at_least(2);
        let d_in = g.dim_at_least(2);
        let n = 3 * (d_in + d_out);
        let x = rand_tensor(g, n, d_in);
        let y = rand_tensor(g, n, d_out);
        let wb = rand_tensor(g, d_out, d_in);
        let delta = g.vec_normal(d_out * d_in, 0.2);
        let mask = PackedMask::pack(&delta, d_out, d_in);
        let r = residual(&x, &y, &wb);
        let st = row_stats(&x, &r, &mask);
        let m_row = mse_rowfam(&st, Axis::Row, &closed_form_rowfam(&st, Axis::Row));
        let m_grp = mse_rowfam(&st, Axis::Group(2), &closed_form_rowfam(&st, Axis::Group(2)));
        let m_sca = mse_rowfam(&st, Axis::Scalar, &closed_form_rowfam(&st, Axis::Scalar));
        if m_row > m_grp + 1e-9 {
            return Err(format!("row {m_row} > group {m_grp}"));
        }
        if m_grp > m_sca + 1e-9 {
            return Err(format!("group {m_grp} > scalar {m_sca}"));
        }
        Ok(())
    });
}

#[test]
fn prop_format_roundtrip() {
    check("pawd-format-roundtrip", 25, 40, |g| {
        let n_modules = 1 + g.rng.below(3);
        let mut modules = Vec::new();
        for k in 0..n_modules {
            let d_out = g.dim_at_least(1);
            let d_in = g.dim_at_least(1);
            let delta = g.vec_normal(d_out * d_in, 1.0);
            let axis = *g.rng.choice(&[Axis::Row, Axis::Col, Axis::Scalar, Axis::Group(4)]);
            modules.push(DeltaModule {
                id: ModuleId { layer: k, kind: ProjKind::ALL[g.rng.below(7)] },
                mask: PackedMask::pack(&delta, d_out, d_in),
                axis,
                scales: g.vec_normal(axis.n_scales(d_out, d_in), 0.1),
                codec: Codec::PerAxis,
            });
        }
        let model = pawd::delta::types::DeltaModel::new(
            format!("v-{}", g.rng.below(1000)),
            "tiny",
            modules,
        );
        let dir = std::env::temp_dir().join("pawd_prop_fmt");
        std::fs::create_dir_all(&dir).ok();
        let path = dir.join("prop.pawd");
        pawd::delta::format::save_delta(&path, &model).map_err(|e| e.to_string())?;
        let loaded = pawd::delta::format::load_delta(&path).map_err(|e| e.to_string())?;
        if loaded.variant != model.variant || loaded.modules.len() != model.modules.len() {
            return Err("header mismatch".into());
        }
        for (a, b) in loaded.modules.iter().zip(&model.modules) {
            if a.mask != b.mask || a.axis != b.axis || a.id != b.id {
                return Err(format!("module mismatch at {}", a.id));
            }
            assert_close(&a.scales, &b.scales, 1e-3, 1e-3)?;
        }
        Ok(())
    });
}

#[test]
fn prop_fidelity_monotone_in_scale_error() {
    // Corrupting the fitted scales can only hurt layer MSE (on average).
    check("scale-corruption-hurts", 15, 16, |g| {
        let d_out = g.dim_at_least(2);
        let d_in = g.dim_at_least(2);
        let n = 4 * (d_in + d_out);
        let x = rand_tensor(g, n, d_in);
        let y = rand_tensor(g, n, d_out);
        let wb = rand_tensor(g, d_out, d_in);
        let delta = g.vec_normal(d_out * d_in, 0.2);
        let mask = PackedMask::pack(&delta, d_out, d_in);
        let r = residual(&x, &y, &wb);
        let st = row_stats(&x, &r, &mask);
        let v = closed_form_rowfam(&st, Axis::Row);
        let base = mse_rowfam(&st, Axis::Row, &v);
        let corrupted: Vec<f32> = v.iter().map(|&x| x * 3.0 + 0.1).collect();
        let worse = mse_rowfam(&st, Axis::Row, &corrupted);
        if worse < base - 1e-9 {
            return Err(format!("corruption improved mse: {worse} < {base}"));
        }
        Ok(())
    });
}
