//! Whole-pipeline smoke at `tiny` scale: pre-train → fine-tune → compress
//! (vector + scalar) → e2e vector training → eval. Checks the key paper
//! orderings rather than absolute numbers. Requires `make artifacts`.

use pawd::baselines;
use pawd::delta::compress::{CompressOptions, FitMode};
use pawd::pipeline::{run_pair, PairConfig};
use std::path::PathBuf;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[test]
fn tiny_pipeline_reproduces_method_orderings() {
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let h = pawd::runtime::start(&artifacts_dir()).expect("runtime");
    // Hyper-parameters validated empirically (see EXPERIMENTS.md): this
    // regime produces a clear base->teacher gap on the fact families.
    let mut pc = PairConfig::quick("tiny");
    pc.base_steps = 800;
    pc.finetune_steps = 400;
    pc.base_lr = 3e-3;
    pc.finetune_lr = 1e-3;
    pc.eval_items_per_family = 30;
    let methods = vec![
        (
            "Vector (row/col)",
            CompressOptions { fit: FitMode::ClosedForm, ..baselines::vector_options() },
            true,
        ),
        (
            "BitDelta (scalar)",
            CompressOptions { fit: FitMode::ClosedForm, ..baselines::bitdelta_options() },
            false,
        ),
    ];
    let out = std::env::temp_dir().join("pawd_itest_pipeline");
    let _ = std::fs::remove_dir_all(&out);
    let res = run_pair(&h, &pc, &methods, &out, |m| eprintln!("{m}")).expect("pipeline");

    // Training worked: loss fell in both phases.
    let (b0, bn) = (res.base_losses[0], *res.base_losses.last().unwrap());
    assert!(bn < b0 * 0.8, "base training loss {b0} -> {bn}");
    assert!(res.finetune_losses.last().unwrap() < &res.finetune_losses[0]);

    // The instruct fine-tune must beat the base on the *fact* families
    // (AttrChain/AttrEasy, the ARC analogs) — that knowledge gap is what
    // the deltas encode. (Template families are noisier at tiny scale:
    // with few held-out template instances the fine-tune can overfit,
    // which the paper's §4 calibration caveat anticipates.)
    use pawd::data::tasks::TaskFamily;
    let facts_avg = |s: &pawd::eval::harness::SuiteResult| {
        (s.pct(TaskFamily::AttrChain) + s.pct(TaskFamily::AttrEasy)) / 200.0
    };
    let base_f = facts_avg(&res.base_suite);
    let teacher_f = facts_avg(&res.baseline_suite);
    assert!(
        teacher_f > base_f + 0.05,
        "fine-tune should beat base on fact families: {teacher_f} vs {base_f}"
    );

    // Vector must not lose to scalar overall (the paper's headline order).
    let vec_avg = res.methods[0].suite.average();
    let sca_avg = res.methods[1].suite.average();
    assert!(
        vec_avg >= sca_avg - 0.03,
        "vector ({vec_avg}) should not lose to scalar ({sca_avg})"
    );
    // And the vector student must recover part of the fact gap.
    let vec_f = facts_avg(&res.methods[0].suite);
    assert!(
        vec_f > base_f,
        "vector ({vec_f}) should recover part of the fact gap (base {base_f}, teacher {teacher_f})"
    );

    // Table-2 shape: artifacts several times smaller than FP16 teacher.
    for m in &res.methods {
        let ratio = res.fp16_bytes as f64 / m.artifact_bytes as f64;
        assert!(ratio > 3.0, "{}: ratio {ratio} too small", m.method);
    }

    // Artifacts exist on disk and load.
    assert!(out.join("teacher.fp16").exists());
    assert!(out.join("vector_row_col".replace(' ', "_")).with_extension("pawd").exists()
        || out.join("vector__row_col_.pawd").exists()
        || std::fs::read_dir(&out).unwrap().count() >= 3);
    h.shutdown();
}
