//! Variant-lifecycle integration: the versioned registry under random
//! publish/rollback/pin/retire sequences, v1-artifact back-compat through
//! the full serving stack, and the headline live-update scenario — a
//! mid-flight publish that flips the alias without failing queued requests.

use pawd::coordinator::{
    Engine, Payload, Server, ServerConfig, VariantRegistry, VariantStore,
};
use pawd::delta::compress::{compress_model, CompressOptions, FitMode};
use pawd::delta::format::{save_delta, save_delta_v1_bytes};
use pawd::delta::pack::PackedMask;
use pawd::delta::types::{Axis, Codec, DeltaModel, DeltaModule};
use pawd::exec::ExecMode;
use pawd::model::config::ModelConfig;
use pawd::model::synth::{synth_finetune, SynthDeltaSpec};
use pawd::model::{FlatParams, ModuleId, ProjKind};
use pawd::util::prop::check;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn tiny_delta(variant: &str) -> DeltaModel {
    let d = vec![1.0f32; 8 * 8];
    DeltaModel::new(
        variant,
        "tiny",
        vec![DeltaModule {
            id: ModuleId { layer: 0, kind: ProjKind::Q },
            mask: PackedMask::pack(&d, 8, 8),
            axis: Axis::Row,
            scales: vec![0.1; 8],
            codec: Codec::PerAxis,
        }],
    )
}

fn compressed_variant(
    name: &str,
    base: &FlatParams,
    seed: u64,
) -> DeltaModel {
    let ft = synth_finetune(base, &SynthDeltaSpec { seed, ..Default::default() });
    let docs: Vec<Vec<u8>> =
        (0..3).map(|i| (0..40).map(|t| ((t * 5 + i * 11) % 200 + 20) as u8).collect()).collect();
    let opts = CompressOptions { fit: FitMode::ClosedForm, ..Default::default() };
    let (delta, _, _) = compress_model(name, base, &ft, &docs, &opts);
    delta
}

fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

// ---------------------------------------------------------------------------
// Property: random lifecycle sequences vs a shadow model
// ---------------------------------------------------------------------------

/// Shadow of one variant's registry state, evolved by the documented rules.
#[derive(Default)]
struct Shadow {
    /// version -> (parent, retired)
    versions: BTreeMap<u32, (Option<u32>, bool)>,
    active: u32,
    pinned: bool,
}

impl Shadow {
    fn max_version(&self) -> u32 {
        self.versions.keys().next_back().copied().unwrap_or(0)
    }

    fn rollback_target(&self) -> Option<u32> {
        let parent = self.versions.get(&self.active).and_then(|(p, _)| *p);
        parent
            .filter(|p| matches!(self.versions.get(p), Some((_, false))))
            .or_else(|| {
                self.versions
                    .range(..self.active)
                    .rev()
                    .find(|(_, (_, retired))| !retired)
                    .map(|(&v, _)| v)
            })
    }
}

#[test]
fn prop_lifecycle_sequences_never_resolve_retired_versions() {
    let case = AtomicU64::new(0);
    check("registry-lifecycle", 24, 10, |g| {
        let dir = fresh_dir(&format!(
            "pawd_prop_registry_{}",
            case.fetch_add(1, Ordering::Relaxed)
        ));
        let reg = VariantRegistry::open(&dir).map_err(|e| e.to_string())?;
        let mut shadow = Shadow::default();
        let n_steps = 3 + g.size * 2;
        for step in 0..n_steps {
            match g.rng.below(6) {
                // publish
                0 | 1 => {
                    let got = reg.publish("ft", tiny_delta("ft")).map_err(|e| e.to_string())?;
                    let want = shadow.max_version() + 1;
                    if got != want {
                        return Err(format!("step {step}: publish gave v{got}, want v{want}"));
                    }
                    shadow.versions.insert(want, (Some(shadow.active).filter(|&a| a > 0), false));
                    if !shadow.pinned {
                        shadow.active = want;
                    }
                }
                // rollback (implicit target)
                2 => {
                    let want = shadow.rollback_target();
                    let got = reg.rollback("ft", None).ok();
                    if got != want {
                        return Err(format!("step {step}: rollback gave {got:?}, want {want:?}"));
                    }
                    if let Some(v) = want {
                        shadow.active = v;
                    }
                }
                // pin a random version in [1, max+1] (may not exist / be retired)
                3 => {
                    let v = 1 + g.rng.below(shadow.max_version() as usize + 1) as u32;
                    let valid = matches!(shadow.versions.get(&v), Some((_, false)));
                    let got = reg.pin("ft", v);
                    if got.is_ok() != valid {
                        return Err(format!("step {step}: pin v{v} ok={} want {valid}", got.is_ok()));
                    }
                    if valid {
                        shadow.active = v;
                        shadow.pinned = true;
                    }
                }
                // retire a random version (must fail for active/unknown)
                4 => {
                    let v = 1 + g.rng.below(shadow.max_version() as usize + 1) as u32;
                    let valid = shadow.versions.contains_key(&v) && v != shadow.active;
                    let got = reg.retire("ft", v);
                    if got.is_ok() != valid {
                        return Err(format!(
                            "step {step}: retire v{v} ok={} want {valid}",
                            got.is_ok()
                        ));
                    }
                    if valid {
                        shadow.versions.get_mut(&v).unwrap().1 = true;
                    }
                }
                // unpin
                _ => {
                    if shadow.max_version() > 0 {
                        reg.unpin("ft").map_err(|e| e.to_string())?;
                        shadow.pinned = false;
                    }
                }
            }
            // Invariants after every step.
            if shadow.max_version() == 0 {
                continue; // nothing published yet
            }
            let r = reg.resolve("ft").map_err(|e| format!("step {step}: resolve: {e}"))?;
            if r.version != shadow.active {
                return Err(format!(
                    "step {step}: alias at v{}, shadow says v{}",
                    r.version, shadow.active
                ));
            }
            if shadow.versions[&r.version].1 {
                return Err(format!("step {step}: alias resolved to RETIRED v{}", r.version));
            }
            for (&v, &(_, retired)) in &shadow.versions {
                let got = reg.resolve(&format!("ft@{v}"));
                if got.is_ok() == retired {
                    return Err(format!(
                        "step {step}: explicit ft@{v} resolvable={} retired={retired}",
                        got.is_ok()
                    ));
                }
            }
        }
        // The manifest must reconstruct the same state on reopen.
        if shadow.max_version() > 0 {
            let reopened = VariantRegistry::open(&dir).map_err(|e| e.to_string())?;
            let r = reopened.resolve("ft").map_err(|e| e.to_string())?;
            if r.version != shadow.active {
                return Err(format!(
                    "reopen: alias at v{}, shadow says v{}",
                    r.version, shadow.active
                ));
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// v1 back-compat through the whole stack
// ---------------------------------------------------------------------------

#[test]
fn v1_artifact_serves_through_registry_store_and_server() {
    let dir = fresh_dir("pawd_itest_v1compat");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = ModelConfig::preset("tiny").unwrap();
    let base = Arc::new(FlatParams::init(&cfg, 77));
    // Write the artifact in the *v1* byte layout, as a pre-registry
    // directory would contain.
    let delta = compressed_variant("legacy", &base, 500);
    std::fs::write(dir.join("legacy.pawd"), save_delta_v1_bytes(&delta)).unwrap();

    let store = VariantStore::new(base.clone(), &dir).with_mode(ExecMode::Fused);
    let loaded = store.load("legacy").unwrap();
    assert_eq!(loaded.version, 1, "adopted v1 artifact is version 1");
    assert!(loaded.weights.is_packed());

    let server = Server::start(store, Engine::Native, ServerConfig::default());
    let client = server.client();
    let resp = client.score("legacy", "Q: legacy probe? A: ", &["a".to_string(), "b".to_string()]);
    assert!(resp.result.is_ok());
    assert_eq!(resp.version, Some(1));
    // Publishing v2 on top of the adopted v1 works and flips the alias.
    // (Staged artifacts live outside the registry dir, as a build pipeline's
    // output would — files inside it get adopted as variants.)
    let staging = fresh_dir("pawd_itest_v1compat_staging");
    std::fs::create_dir_all(&staging).unwrap();
    let staged = staging.join("staged.pawd");
    save_delta(&staged, &compressed_variant("legacy", &base, 501)).unwrap();
    assert_eq!(client.publish("legacy", &staged), Ok(2));
    let resp = client.score("legacy", "Q: legacy probe? A: ", &["a".to_string(), "b".to_string()]);
    assert_eq!(resp.version, Some(2));
    server.shutdown();
}

// ---------------------------------------------------------------------------
// The headline scenario: publish mid-flight, no failed requests
// ---------------------------------------------------------------------------

#[test]
fn mid_flight_publish_flips_alias_without_failing_requests() {
    let dir = fresh_dir("pawd_itest_midflight");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = ModelConfig::preset("tiny").unwrap();
    let base = Arc::new(FlatParams::init(&cfg, 77));
    save_delta(dir.join("var0.pawd"), &compressed_variant("var0", &base, 600)).unwrap();
    let staging = fresh_dir("pawd_itest_midflight_staging");
    std::fs::create_dir_all(&staging).unwrap();
    let staged = staging.join("var0_v2.pawd");
    save_delta(&staged, &compressed_variant("var0", &base, 601)).unwrap();

    let store = VariantStore::new(base.clone(), &dir).with_mode(ExecMode::Fused);
    let server = Server::start(
        store,
        Engine::Native,
        ServerConfig { n_workers: 2, ..Default::default() },
    );
    let stop = AtomicBool::new(false);
    let saw_v1 = AtomicU64::new(0);
    let saw_v2 = AtomicU64::new(0);
    std::thread::scope(|s| {
        // Background traffic: every request must succeed across the flip.
        for t in 0..3u64 {
            let client = server.client();
            let (stop, saw_v1, saw_v2) = (&stop, &saw_v1, &saw_v2);
            s.spawn(move || {
                let mut i = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let resp = client.score(
                        "var0",
                        &format!("Q: traffic {t}/{i}? A: "),
                        &["yes".to_string(), "no".to_string()],
                    );
                    assert!(
                        resp.result.is_ok(),
                        "request failed across the publish flip: {:?}",
                        resp.result
                    );
                    match resp.version {
                        Some(1) => {
                            saw_v1.fetch_add(1, Ordering::Relaxed);
                        }
                        Some(2) => {
                            saw_v2.fetch_add(1, Ordering::Relaxed);
                        }
                        v => panic!("unexpected serving version {v:?}"),
                    }
                    i += 1;
                }
            });
        }
        let admin = server.client();
        // Let some v1 traffic through, then publish mid-flight.
        let deadline = Instant::now() + Duration::from_secs(10);
        while saw_v1.load(Ordering::Relaxed) < 8 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(saw_v1.load(Ordering::Relaxed) >= 8, "no v1 traffic before publish");
        let v2 = admin.publish("var0", &staged).expect("publish while serving");
        assert_eq!(v2, 2);
        // Every request *submitted* after the publish response resolves to
        // v2 at execution time; the probe proves the flip.
        let probe = admin.score("var0", "Q: post-publish probe? A: ", &["x".to_string(), "y".to_string()]);
        assert_eq!(probe.version, Some(2), "alias did not flip to the published version");
        let deadline = Instant::now() + Duration::from_secs(10);
        while saw_v2.load(Ordering::Relaxed) < 8 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(saw_v2.load(Ordering::Relaxed) >= 8, "traffic never moved to v2");
        // Rollback restores v1 for subsequent requests — still no failures.
        assert_eq!(admin.rollback("var0", None), Ok(1));
        let probe = admin.score("var0", "Q: post-rollback probe? A: ", &["x".to_string(), "y".to_string()]);
        assert_eq!(probe.version, Some(1), "rollback did not restore v1");
        stop.store(true, Ordering::Relaxed);
    });
    // Both versions served traffic; nothing errored; both resided at once
    // (the publish warmed v2 while v1 stayed resident).
    assert!(saw_v1.load(Ordering::Relaxed) > 0 && saw_v2.load(Ordering::Relaxed) > 0);
    let snap = server.metrics.snapshot();
    assert_eq!(snap.errors, 0, "lifecycle flips must not fail requests");
    assert_eq!((snap.publishes, snap.rollbacks), (1, 1));
    let resident = server.cache.resident();
    assert!(resident.contains(&("var0".to_string(), 1)));
    assert!(resident.contains(&("var0".to_string(), 2)));
    server.shutdown();
}

// ---------------------------------------------------------------------------
// Control-plane odds and ends through the request path
// ---------------------------------------------------------------------------

#[test]
fn admin_list_pin_and_retire_through_the_server() {
    let dir = fresh_dir("pawd_itest_adminops");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = ModelConfig::preset("tiny").unwrap();
    let base = Arc::new(FlatParams::init(&cfg, 3));
    save_delta(dir.join("a.pawd"), &compressed_variant("a", &base, 700)).unwrap();
    let staging = fresh_dir("pawd_itest_adminops_staging");
    std::fs::create_dir_all(&staging).unwrap();
    let staged = staging.join("staged.pawd");
    save_delta(&staged, &compressed_variant("a", &base, 701)).unwrap();

    let store = VariantStore::new(base, &dir);
    let server = Server::start(store, Engine::Native, ServerConfig::default());
    let client = server.client();

    use pawd::coordinator::{AdminOp, AdminResp};
    // Pin v1, publish v2: the alias must not move.
    match client.admin(AdminOp::Pin { variant: "a".into(), version: 1 }) {
        Ok(AdminResp::Pinned { version: 1, .. }) => {}
        other => panic!("unexpected {other:?}"),
    }
    assert_eq!(client.publish("a", &staged), Ok(2));
    let resp = client.score("a", "Q: pinned? A: ", &["x".to_string(), "y".to_string()]);
    assert_eq!(resp.version, Some(1), "pinned alias moved on publish");
    // Retire the unused v2, list shows the full history.
    match client.admin(AdminOp::Retire { variant: "a".into(), version: 2 }) {
        Ok(AdminResp::Retired { version: 2, .. }) => {}
        other => panic!("unexpected {other:?}"),
    }
    let descs = client.variants().unwrap();
    assert_eq!(descs.len(), 1);
    assert_eq!((descs[0].active, descs[0].pinned), (1, true));
    assert_eq!(descs[0].versions.len(), 2);
    assert!(descs[0].versions[1].retired);
    // Retired versions refuse data requests by explicit address.
    let resp = client.score("a@2", "Q: retired? A: ", &["x".to_string(), "y".to_string()]);
    assert!(resp.result.is_err());
    // The lifecycle counters made it into the snapshot.
    let stats = client.stats().unwrap();
    assert_eq!(stats.publishes, 1);
    server.shutdown();
}

#[test]
fn gc_through_the_server_frees_retired_artifacts_mid_traffic() {
    let dir = fresh_dir("pawd_itest_admingc");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = ModelConfig::preset("tiny").unwrap();
    let base = Arc::new(FlatParams::init(&cfg, 3));
    save_delta(dir.join("a.pawd"), &compressed_variant("a", &base, 710)).unwrap();
    let staging = fresh_dir("pawd_itest_admingc_staging");
    std::fs::create_dir_all(&staging).unwrap();
    let staged = staging.join("staged.pawd");
    save_delta(&staged, &compressed_variant("a", &base, 711)).unwrap();

    let store = VariantStore::new(base, &dir).with_mode(ExecMode::Fused);
    let server = Server::start(store, Engine::Native, ServerConfig::default());
    let client = server.client();

    // v1 is resident (serve it once), then superseded and retired.
    let r1 = client.score("a", "Q: v1? A: ", &["x".to_string(), "y".to_string()]);
    assert_eq!(r1.version, Some(1));
    assert_eq!(client.publish("a", &staged), Ok(2));
    use pawd::coordinator::{AdminOp, AdminResp};
    match client.admin(AdminOp::Retire { variant: "a".into(), version: 1 }) {
        Ok(AdminResp::Retired { version: 1, .. }) => {}
        other => panic!("unexpected {other:?}"),
    }
    let v1_file = dir.join("a.pawd"); // adopted legacy artifact backs v1
    assert!(v1_file.exists());
    let (files, bytes) = client.gc(Some("a")).unwrap();
    assert_eq!(files, 1);
    assert!(bytes > 0);
    assert!(!v1_file.exists(), "retired artifact must be unlinked");
    // The active version is untouched and still serves.
    let r2 = client.score("a", "Q: v2? A: ", &["x".to_string(), "y".to_string()]);
    assert_eq!(r2.version, Some(2));
    assert!(r2.result.is_ok());
    // History still lists v1 as a retired tombstone.
    let descs = client.variants().unwrap();
    assert_eq!(descs[0].versions.len(), 2);
    assert!(descs[0].versions[0].retired && descs[0].versions[0].file.is_empty());
    // A second sweep has nothing to do.
    assert_eq!(client.gc(None), Ok((0, 0)));
    server.shutdown();
}

#[test]
fn admin_ops_route_by_payload_not_variant_name() {
    // The deprecated `__stats__` pseudo-variant alias is gone: admin
    // routing is by payload type alone, and the `__admin__` pseudo-variant
    // still rejects misdirected data ops.
    let dir = fresh_dir("pawd_itest_adminroute");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = ModelConfig::preset("tiny").unwrap();
    let base = Arc::new(FlatParams::init(&cfg, 3));
    save_delta(dir.join("a.pawd"), &compressed_variant("a", &base, 800)).unwrap();
    let server = Server::start(
        VariantStore::new(base, &dir),
        Engine::Native,
        ServerConfig::default(),
    );
    let client = server.client();
    let _ = client.score("a", "Q: warm? A: ", &["x".to_string(), "y".to_string()]);
    // An Admin payload routes to the control plane regardless of the
    // variant string it rides under — even a data variant's name.
    use pawd::coordinator::{AdminOp, RespBody, ADMIN_VARIANT};
    let rx = client.submit("a", Payload::Admin(AdminOp::Stats));
    match rx.recv().unwrap().result {
        Ok(RespBody::Admin(pawd::coordinator::AdminResp::Stats { snapshot })) => {
            assert!(snapshot.served >= 1);
        }
        other => panic!("unexpected {other:?}"),
    }
    // The typed client helper is the supported surface.
    assert!(client.stats().unwrap().served >= 1);
    // A *data* op aimed at the reserved admin pseudo-variant is rejected.
    let resp = client.score(ADMIN_VARIANT, "Q: ? A: ", &["x".to_string()]);
    assert!(resp.result.is_err());
    assert!(resp.result.unwrap_err().contains("reserved"));
    // The retired `__stats__` name is now just an unknown (unpublishable)
    // variant: a data op against it fails variant resolution.
    let resp = client.score("__stats__", "Q: ? A: ", &["x".to_string()]);
    assert!(resp.result.is_err());
    server.shutdown();
}

#[test]
fn incremental_publish_through_the_server_warms_from_the_resident_parent() {
    let dir = fresh_dir("pawd_itest_incpublish");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = ModelConfig::preset("tiny").unwrap();
    let base = Arc::new(FlatParams::init(&cfg, 11));
    save_delta(dir.join("a.pawd"), &compressed_variant("a", &base, 900)).unwrap();
    let staging = fresh_dir("pawd_itest_incpublish_staging");
    std::fs::create_dir_all(&staging).unwrap();

    let store = VariantStore::new(base.clone(), &dir).with_mode(ExecMode::Fused);
    let server = Server::start(store, Engine::Native, ServerConfig::default());
    let client = server.client();
    // Warm v1, then stage a child model that differs in a single module.
    let r1 = client.score("a", "Q: v1? A: ", &["x".to_string(), "y".to_string()]);
    assert_eq!(r1.version, Some(1));
    let mut child = pawd::delta::format::load_delta(dir.join("a.pawd")).unwrap();
    {
        let m = Arc::make_mut(&mut child.modules[0]);
        for s in &mut m.scales {
            *s *= 2.0;
        }
    }
    let staged = staging.join("child.pawd");
    save_delta(&staged, &child).unwrap();
    let full_bytes = std::fs::metadata(&staged).unwrap().len();
    let (version, patch, bytes) = client.publish_incremental("a", &staged, None).unwrap();
    assert_eq!(version, 2);
    assert!(patch, "single-module change must ship as a patch");
    assert!(
        bytes * 2 < full_bytes,
        "patch bytes {bytes} should be well under the full artifact {full_bytes}"
    );
    // The flip is live and serves the composed chain.
    let r2 = client.score("a", "Q: v2? A: ", &["x".to_string(), "y".to_string()]);
    assert_eq!(r2.version, Some(2));
    assert!(r2.result.is_ok());
    // Both versions resident; consolidation through the admin plane keeps
    // the version serving and collapses its chain.
    let resident = server.cache.resident();
    assert!(resident.contains(&("a".to_string(), 1)));
    assert!(resident.contains(&("a".to_string(), 2)));
    assert_eq!(client.consolidate("a", None), Ok(2));
    let r3 = client.score("a", "Q: post-consolidate? A: ", &["x".to_string(), "y".to_string()]);
    assert_eq!(r3.version, Some(2));
    assert!(r3.result.is_ok());
    server.shutdown();
}
