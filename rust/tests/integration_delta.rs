//! Cross-module delta integration: full compress→serialize→load→apply
//! round trips, and the paper's method ordering (Vector ≥ Scalar) measured
//! by teacher fidelity on held-out text.

use pawd::baselines;
use pawd::delta::compress::{compress_model, CompressOptions, FitMode};
use pawd::delta::format::{load_delta, save_delta};
use pawd::delta::stats::delta_stats;
use pawd::delta::types::Axis;
use pawd::eval::fidelity::fidelity;
use pawd::model::config::ModelConfig;
use pawd::model::synth::{synth_finetune, SynthDeltaSpec};
use pawd::model::{FlatParams, Transformer};

fn calib_docs(n: usize, len: usize) -> Vec<Vec<u8>> {
    (0..n)
        .map(|i| (0..len).map(|t| ((t * 7 + i * 29) % 220 + 10) as u8).collect())
        .collect()
}

/// Probe documents drawn from the same generator family as the calibration
/// docs (different instances). Matching distributions matters: the paper's
/// §4 notes activation-aware calibration degrades under distribution shift,
/// which random byte streams amplify.
fn probe_docs() -> Vec<Vec<u8>> {
    (10..14)
        .map(|i| (0..48).map(|t| ((t * 7 + i * 29) % 220 + 10) as u8).collect())
        .collect()
}

#[test]
fn full_roundtrip_reconstruction_improves_fidelity() {
    let cfg = ModelConfig::preset("tiny").unwrap();
    let base = FlatParams::init(&cfg, 21);
    let ft = synth_finetune(&base, &SynthDeltaSpec { magnitude: 0.03, ..Default::default() });
    let opts = CompressOptions { fit: FitMode::ClosedForm, ..Default::default() };
    let (delta, _, _) = compress_model("ft", &base, &ft, &calib_docs(5, 40), &opts);

    let dir = std::env::temp_dir().join("pawd_itest_delta");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ft.pawd");
    save_delta(&path, &delta).unwrap();
    let loaded = load_delta(&path).unwrap();
    let student = pawd::delta::apply::materialize(&base, &loaded.modules);

    let tf = Transformer::new(&cfg);
    let probes = probe_docs();
    let f_base = fidelity(&tf, &ft, &base, &probes);
    let f_student = fidelity(&tf, &ft, &student, &probes);
    assert!(
        f_student.kl < f_base.kl * 0.75,
        "student KL {} should be well under base {}",
        f_student.kl,
        f_base.kl
    );
    assert!(f_student.agreement >= f_base.agreement);
}

#[test]
fn vector_beats_scalar_on_anisotropic_finetune() {
    let cfg = ModelConfig::preset("tiny").unwrap();
    let base = FlatParams::init(&cfg, 22);
    let ft = synth_finetune(
        &base,
        &SynthDeltaSpec { magnitude: 0.03, anisotropy: 1.3, axis_bias: 0.7, seed: 4 },
    );
    let docs = calib_docs(6, 40);
    let o_vec = CompressOptions { fit: FitMode::ClosedForm, ..baselines::vector_options() };
    let o_sca = CompressOptions { fit: FitMode::ClosedForm, ..baselines::bitdelta_options() };
    let (d_vec, _, _) = compress_model("v", &base, &ft, &docs, &o_vec);
    let (d_sca, _, _) = compress_model("s", &base, &ft, &docs, &o_sca);
    let tf = Transformer::new(&cfg);
    let probes = probe_docs();
    let s_vec = pawd::delta::apply::materialize(&base, &d_vec.modules);
    let s_sca = pawd::delta::apply::materialize(&base, &d_sca.modules);
    let f_vec = fidelity(&tf, &ft, &s_vec, &probes);
    let f_sca = fidelity(&tf, &ft, &s_sca, &probes);
    assert!(
        f_vec.kl < f_sca.kl,
        "vector KL {} must beat scalar KL {} (anisotropic delta)",
        f_vec.kl,
        f_sca.kl
    );
}

#[test]
fn scalar_matches_vector_on_isotropic_delta() {
    // Paper §4 limitation: near-isotropic deltas -> scalar is enough.
    let cfg = ModelConfig::preset("tiny").unwrap();
    let base = FlatParams::init(&cfg, 23);
    let ft = synth_finetune(
        &base,
        &SynthDeltaSpec { magnitude: 0.03, anisotropy: 0.0, axis_bias: 0.5, seed: 5 },
    );
    let docs = calib_docs(6, 40);
    let o_vec = CompressOptions { fit: FitMode::ClosedForm, ..baselines::vector_options() };
    let o_sca = CompressOptions { fit: FitMode::ClosedForm, ..baselines::bitdelta_options() };
    let (d_vec, _, _) = compress_model("v", &base, &ft, &docs, &o_vec);
    let (d_sca, _, _) = compress_model("s", &base, &ft, &docs, &o_sca);
    let tf = Transformer::new(&cfg);
    let probes = probe_docs();
    let f_vec = fidelity(&tf, &ft, &pawd::delta::apply::materialize(&base, &d_vec.modules), &probes);
    let f_sca = fidelity(&tf, &ft, &pawd::delta::apply::materialize(&base, &d_sca.modules), &probes);
    // Scalar should be within ~25% of vector (not catastrophically worse).
    assert!(
        f_sca.kl < f_vec.kl * 1.25 + 1e-6,
        "isotropic: scalar {} should track vector {}",
        f_sca.kl,
        f_vec.kl
    );
}

#[test]
fn groupwise_sits_between_vector_and_scalar() {
    let cfg = ModelConfig::preset("tiny").unwrap();
    let base = FlatParams::init(&cfg, 24);
    let ft = synth_finetune(
        &base,
        &SynthDeltaSpec { magnitude: 0.03, anisotropy: 1.4, axis_bias: 1.0, seed: 6 },
    );
    let docs = calib_docs(6, 40);
    let fit = FitMode::ClosedForm;
    let mk = |axes: Vec<Axis>| CompressOptions { fit, axes, ..Default::default() };
    let tf = Transformer::new(&cfg);
    let probes = probe_docs();
    let kl_of = |axes: Vec<Axis>| {
        let (d, _, _) = compress_model("x", &base, &ft, &docs, &mk(axes));
        fidelity(&tf, &ft, &pawd::delta::apply::materialize(&base, &d.modules), &probes).kl
    };
    let kl_row = kl_of(vec![Axis::Row]);
    let kl_g8 = kl_of(vec![Axis::Group(8)]);
    let kl_scalar = kl_of(vec![Axis::Scalar]);
    assert!(kl_row <= kl_g8 * 1.05, "row {kl_row} vs group8 {kl_g8}");
    assert!(kl_g8 <= kl_scalar * 1.05, "group8 {kl_g8} vs scalar {kl_scalar}");
}

#[test]
fn anisotropy_stats_reflect_synth_spec() {
    let cfg = ModelConfig::preset("tiny").unwrap();
    let base = FlatParams::init(&cfg, 25);
    let iso = synth_finetune(&base, &SynthDeltaSpec { anisotropy: 0.0, seed: 7, ..Default::default() });
    let aniso = synth_finetune(
        &base,
        &SynthDeltaSpec { anisotropy: 1.5, axis_bias: 1.0, seed: 7, ..Default::default() },
    );
    let id = base.layout.patchable_modules()[0];
    let (rows, cols) = id.kind.shape(&cfg);
    let s_iso = delta_stats(base.module(id), iso.module(id), rows, cols);
    let s_aniso = delta_stats(base.module(id), aniso.module(id), rows, cols);
    assert!(s_aniso.row_cv > s_iso.row_cv * 3.0, "{} vs {}", s_aniso.row_cv, s_iso.row_cv);
}

#[test]
fn calibration_beats_magnitude_only_init_on_layer_mse() {
    // The guaranteed invariant is at the layer-output level: the fitted
    // scales minimize held-out layer MSE, which the mean(|ΔW|) init does
    // not. (Downstream KL from 5 random calibration docs is noisier — the
    // paper's §4 distribution-shift caveat.)
    let cfg = ModelConfig::preset("tiny").unwrap();
    let base = FlatParams::init(&cfg, 26);
    let ft = synth_finetune(&base, &SynthDeltaSpec { magnitude: 0.03, ..Default::default() });
    // Enough calibration rows that the col-mode fit (up to d_in scales) is
    // well-posed — with too few docs the exact minimizer can overfit its
    // train shard and lose on validation, which is the paper's motivation
    // for the 50-sample budget.
    let docs = calib_docs(24, 48);
    let o_cal = CompressOptions { fit: FitMode::ClosedForm, ..Default::default() };
    let o_mag = baselines::magnitude_only_options();
    let (_, rep_cal, _) = compress_model("c", &base, &ft, &docs, &o_cal);
    let (_, rep_mag, _) = compress_model("m", &base, &ft, &docs, &o_mag);
    // Only layer-0 modules see identical caches in both runs (later layers
    // calibrate against each run's own partially-compressed student), so
    // restrict the strict comparison to layer 0.
    let mut wins = 0;
    let mut total = 0;
    for (rc, rm) in rep_cal.iter().zip(&rep_mag) {
        if rc.id.layer != 0 {
            continue;
        }
        total += 1;
        let c = rc.candidates.iter().map(|x| x.2).fold(f64::INFINITY, f64::min);
        let m = rm.candidates.iter().map(|x| x.2).fold(f64::INFINITY, f64::min);
        if c <= m * 1.001 {
            wins += 1;
        }
    }
    assert_eq!(
        wins, total,
        "calibrated val MSE must beat init on every layer-0 module: {wins}/{total}"
    );
}
