//! Serving-stack integration: compress variants to disk, start the
//! coordinator, drive concurrent clients, check correctness of scoring,
//! batching, caching and cold-start accounting.

use pawd::coordinator::{Engine, Payload, RespBody, Server, ServerConfig, VariantStore};
use pawd::data::tasks::{eval_items, TaskFamily};
use pawd::data::World;
use pawd::delta::compress::{compress_model, CompressOptions, FitMode};
use pawd::delta::format::save_delta;
use pawd::eval::harness::predict;
use pawd::exec::ExecMode;
use pawd::model::config::ModelConfig;
use pawd::model::synth::{synth_finetune, SynthDeltaSpec};
use pawd::model::{FlatParams, Transformer};
use std::path::PathBuf;
use std::sync::Arc;

fn setup_store(dir: &PathBuf, n_variants: usize) -> (Arc<FlatParams>, VariantStore) {
    let _ = std::fs::remove_dir_all(dir);
    std::fs::create_dir_all(dir).unwrap();
    let cfg = ModelConfig::preset("tiny").unwrap();
    let base = Arc::new(FlatParams::init(&cfg, 77));
    let docs: Vec<Vec<u8>> = (0..3).map(|i| {
        (0..40).map(|t| ((t * 5 + i * 11) % 200 + 20) as u8).collect()
    }).collect();
    let opts = CompressOptions { fit: FitMode::ClosedForm, ..Default::default() };
    for k in 0..n_variants {
        let ft = synth_finetune(
            &base,
            &SynthDeltaSpec { seed: 500 + k as u64, ..Default::default() },
        );
        let (delta, _, _) = compress_model(&format!("var{k}"), &base, &ft, &docs, &opts);
        save_delta(dir.join(format!("var{k}.pawd")), &delta).unwrap();
    }
    let store = VariantStore::new(base.clone(), dir);
    (base, store)
}

#[test]
fn serves_score_requests_and_matches_direct_eval() {
    let dir = std::env::temp_dir().join("pawd_itest_serve1");
    let (base, store) = setup_store(&dir, 1);
    // Dense mode: the server must agree with the direct materialized eval
    // bit-for-bit (same arithmetic), so argmax equality is exact.
    let server = Server::start(
        store,
        Engine::Native,
        ServerConfig { exec: ExecMode::Dense, ..Default::default() },
    );
    let client = server.client();

    // Ground truth: materialize the variant directly and use the harness.
    let loaded = VariantStore::new(base.clone(), &dir).load("var0").unwrap();
    let params = loaded.params();
    let tf = Transformer::new(base.cfg());
    let world = World::generate(9, 24);
    let items = eval_items(&world, TaskFamily::AttrEasy, 12, 3);
    for item in &items {
        let resp = client.score("var0", &item.prompt, &item.choices);
        let direct = predict(&tf, &params, item);
        match resp.result {
            Ok(RespBody::Score { choice, ref scores }) => {
                assert_eq!(choice, direct, "server and direct eval disagree");
                assert_eq!(scores.len(), item.choices.len());
            }
            ref other => panic!("unexpected response {other:?}"),
        }
        assert!(resp.timing.total >= resp.timing.compute);
    }
    server.shutdown();
}

#[test]
fn fused_mode_matches_dense_mode_scores() {
    // The dense-vs-fused A/B: same store, two servers, per-choice scores
    // must agree to f32 accumulation noise (the fused path never
    // materializes Ŵ, so the arithmetic differs in summation order only).
    let dir = std::env::temp_dir().join("pawd_itest_serve_fused");
    let (_base, store) = setup_store(&dir, 2);
    let dense = Server::start(
        store.clone(),
        Engine::Native,
        ServerConfig { exec: ExecMode::Dense, ..Default::default() },
    );
    // Single worker so the alternating var0/var1 stream is observed by one
    // worker — with 2 workers the blocked-recv rotation pins each worker to
    // one variant and the swap counter would (correctly) stay at zero.
    let fused = Server::start(
        store,
        Engine::Native,
        ServerConfig { exec: ExecMode::Fused, n_workers: 1, ..Default::default() },
    );
    let (dc, fc) = (dense.client(), fused.client());
    let world = World::generate(11, 24);
    let items = eval_items(&world, TaskFamily::AttrEasy, 10, 4);
    for item in &items {
        for v in ["var0", "var1"] {
            let rd = dc.score(v, &item.prompt, &item.choices);
            let rf = fc.score(v, &item.prompt, &item.choices);
            match (rd.result, rf.result) {
                (
                    Ok(RespBody::Score { scores: sd, .. }),
                    Ok(RespBody::Score { scores: sf, .. }),
                ) => {
                    for (a, b) in sd.iter().zip(&sf) {
                        assert!(
                            (a - b).abs() < 1e-4 * (1.0 + b.abs()),
                            "dense/fused score mismatch on {v}: {a} vs {b}"
                        );
                    }
                }
                other => panic!("unexpected {other:?}"),
            }
        }
    }
    // Fused residency: both variants resident at a fraction of dense bytes,
    // observed through the stats request endpoint.
    let stats = fc.stats().expect("stats endpoint");
    assert_eq!(stats.resident_variants, 2);
    assert!(stats.resident_bytes * 4 < stats.resident_dense_equiv_bytes);
    assert!(stats.swaps >= 1, "worker must have hot-swapped between variants");
    dense.shutdown();
    fused.shutdown();
}

#[test]
fn batches_form_and_cold_start_is_recorded() {
    let dir = std::env::temp_dir().join("pawd_itest_serve2");
    let (_base, store) = setup_store(&dir, 2);
    let server = Server::start(
        store,
        Engine::Native,
        ServerConfig { max_batch: 4, ..Default::default() },
    );
    let client = server.client();
    // Fire a burst of async requests at one variant so they batch.
    let rxs: Vec<_> = (0..8)
        .map(|i| {
            client.submit(
                "var0",
                Payload::score(&format!("Q: item {i}? A: "), &["yes".into(), "no".into()]),
            )
        })
        .collect();
    for rx in rxs {
        let resp = rx.recv().unwrap();
        assert!(resp.result.is_ok());
    }
    let snap = server.metrics.snapshot();
    assert_eq!(snap.served, 8);
    assert!(snap.mean_batch_size > 1.0, "expected batching, got {}", snap.mean_batch_size);
    assert_eq!(snap.cold_starts, 1, "exactly one cold load for var0");
    server.shutdown();
}

#[test]
fn multi_variant_concurrent_clients() {
    let dir = std::env::temp_dir().join("pawd_itest_serve3");
    let (_base, store) = setup_store(&dir, 3);
    let server = Server::start(store, Engine::Native, ServerConfig::default());
    let n_ok = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|s| {
        for t in 0..6 {
            let client = server.client();
            let n_ok = &n_ok;
            s.spawn(move || {
                for i in 0..10 {
                    let variant = format!("var{}", (t + i) % 3);
                    let resp = client.score(
                        &variant,
                        "Q: what is the color of bela? A: ",
                        &["red".to_string(), "blue".to_string()],
                    );
                    assert_eq!(resp.variant, variant);
                    if resp.result.is_ok() {
                        n_ok.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    }
                }
            });
        }
    });
    assert_eq!(n_ok.load(std::sync::atomic::Ordering::Relaxed), 60);
    let snap = server.metrics.snapshot();
    assert_eq!(snap.served, 60);
    assert_eq!(snap.per_variant.len(), 3);
    assert_eq!(snap.errors, 0);
    server.shutdown();
}

#[test]
fn unknown_variant_yields_error_response() {
    let dir = std::env::temp_dir().join("pawd_itest_serve4");
    let (_base, store) = setup_store(&dir, 1);
    let server = Server::start(store, Engine::Native, ServerConfig::default());
    let client = server.client();
    let resp = client.score("ghost", "Q: ? A: ", &["a".to_string(), "b".to_string()]);
    assert!(resp.result.is_err());
    let snap = server.metrics.snapshot();
    assert_eq!(snap.errors, 1);
    server.shutdown();
}

#[test]
fn perplexity_requests_work() {
    let dir = std::env::temp_dir().join("pawd_itest_serve5");
    let (_base, store) = setup_store(&dir, 1);
    let server = Server::start(store, Engine::Native, ServerConfig::default());
    let client = server.client();
    let rx = client.submit("var0", Payload::perplexity("the mill by the river turns all day."));
    match rx.recv().unwrap().result {
        Ok(RespBody::Perplexity { nats_per_token }) => {
            assert!(nats_per_token > 0.0 && nats_per_token < 10.0);
        }
        other => panic!("unexpected {other:?}"),
    }
    server.shutdown();
}

#[test]
fn eviction_under_tight_budget_still_serves() {
    let dir = std::env::temp_dir().join("pawd_itest_serve6");
    let (base, store) = setup_store(&dir, 3);
    // Dense mode: a budget of one materialized variant forces churn. (In
    // fused mode the same budget would hold the whole fleet — covered by
    // the packed-residency cache test.)
    let one_variant = (base.data.len() * 4) as u64;
    let server = Server::start(
        store,
        Engine::Native,
        ServerConfig {
            cache_budget_bytes: one_variant + 1024,
            exec: ExecMode::Dense,
            ..Default::default()
        },
    );
    let client = server.client();
    for round in 0..2 {
        for k in 0..3 {
            let resp = client.score(
                &format!("var{k}"),
                "Q: probe? A: ",
                &["x".to_string(), "y".to_string()],
            );
            assert!(resp.result.is_ok(), "round {round} var{k}");
        }
    }
    let stats = server.cache.stats();
    assert!(stats.evictions >= 3, "tight budget must evict, got {}", stats.evictions);
    assert!(server.cache.used_bytes() <= one_variant + 1024);
    server.shutdown();
}

#[test]
fn fused_mode_holds_whole_fleet_in_one_dense_budget() {
    let dir = std::env::temp_dir().join("pawd_itest_serve8");
    let (base, store) = setup_store(&dir, 3);
    let one_variant = (base.data.len() * 4) as u64;
    // Default config = fused mode: the budget that evicts constantly in
    // dense mode keeps every packed variant resident, so round two is all
    // cache hits and zero evictions.
    let server = Server::start(
        store,
        Engine::Native,
        ServerConfig { cache_budget_bytes: one_variant + 1024, ..Default::default() },
    );
    let client = server.client();
    for round in 0..2 {
        for k in 0..3 {
            let resp = client.score(
                &format!("var{k}"),
                "Q: probe? A: ",
                &["x".to_string(), "y".to_string()],
            );
            assert!(resp.result.is_ok(), "round {round} var{k}");
        }
    }
    let stats = server.cache.stats();
    assert_eq!(stats.evictions, 0, "packed fleet must fit the dense-single budget");
    assert_eq!(stats.misses, 3, "each variant cold-loads exactly once");
    assert_eq!(server.cache.resident_names().len(), 3);
    server.shutdown();
}

#[test]
fn xla_engine_agrees_with_native_engine() {
    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let dir = std::env::temp_dir().join("pawd_itest_serve7");
    let (_base, store) = setup_store(&dir, 1);
    let (_b2, store2) = setup_store(&std::env::temp_dir().join("pawd_itest_serve7b"), 1);
    let h = pawd::runtime::start(&artifacts).unwrap();
    let native = Server::start(store, Engine::Native, ServerConfig::default());
    let xla = Server::start(
        store2,
        Engine::Xla { handle: h.clone(), config: "tiny".into() },
        ServerConfig { n_workers: 1, ..Default::default() },
    );
    // Short prompts only: the engines clamp to different context lengths
    // (native: cfg.max_seq=64; XLA: largest fwd bucket=48), so items longer
    // than the smaller bound legitimately see different contexts.
    let items: Vec<pawd::data::McItem> = (0..8)
        .map(|i| pawd::data::McItem {
            family: TaskFamily::Physical,
            prompt: format!("Q: probe {i}? A: "),
            choices: vec!["twist the lid".into(), "shake the jar".into()],
            correct: 0,
        })
        .collect();
    let (nc, xc) = (native.client(), xla.client());
    for item in &items {
        let rn = nc.score("var0", &item.prompt, &item.choices);
        let rx = xc.score("var0", &item.prompt, &item.choices);
        match (rn.result, rx.result) {
            (
                Ok(RespBody::Score { choice: a, scores: sa }),
                Ok(RespBody::Score { choice: b, scores: sb }),
            ) => {
                // Per-choice scores must agree numerically; the argmax may
                // legitimately flip when two choices are within f32
                // accumulation noise of each other.
                for (x, y) in sa.iter().zip(&sb) {
                    assert!(
                        (x - y).abs() < 5e-3 * (1.0 + y.abs()),
                        "score mismatch on {:?}: {x} vs {y}",
                        item.prompt
                    );
                }
                if a != b {
                    let gap = (sa[a] - sa[b]).abs();
                    assert!(gap < 5e-3, "argmax differs with non-tiny gap {gap}");
                }
            }
            other => panic!("unexpected {other:?}"),
        }
    }
    native.shutdown();
    xla.shutdown();
    h.shutdown();
}
