#!/usr/bin/env python3
"""Toolchain-less mirror of `pawd audit` (rust/src/audit/).

The Rust analyzer is the authoritative implementation — it runs in tier-1
CI (`rust/tests/audit_self.rs`) and as `pawd audit [--json]`. This script
re-implements the same passes with the same finding codes so the audit can
run pre-commit in containers that have no Rust toolchain (the environment
this repo has been grown in). `scripts/audit.sh` prefers the Rust binary
and falls back to this mirror.

Passes (stable finding codes):
  A001 bracket-balance      delimiter/string/comment balance per .rs file
  A002 use-resolution       crate-internal use paths resolve to pub items
  A003 match-exhaustive     matches over grown enums cover every variant
  A101 counter-drift        exec/counters == MetricsSnapshot == wire keys
                            == serve summary refs == README counter table
  A102 env-drift            PAWD_* env reads == README env table
  A103 route-drift          AdminOp variants == admin_routes::ALL == README
  A104 bench-key-drift      BENCH_baseline.json gated keys exist in benches
  A201 unsafe-safety        every unsafe site carries a SAFETY comment
  A202 unsafe-inventory     per-file unsafe counts match the golden file
  A203 condvar-wait-in-loop condvar waits sit inside a re-checking loop

Suppress a finding with `// audit:allow(<pass-name>)` on the same line or
the line above the site.

Exit status: 0 = clean, 1 = findings, 2 = analyzer error.
"""

import json
import os
import re
import sys

IDENT = re.compile(r"[A-Za-z_][A-Za-z0-9_]*")

# Grown enums: matches over these must stay exhaustive (file, enum name).
GROWN_ENUMS = [
    ("rust/src/coordinator/request.rs", "AdminOp"),
    ("rust/src/coordinator/request.rs", "Payload"),
    ("rust/src/coordinator/engine.rs", "Ingress"),
    ("rust/src/delta/compress.rs", "CodecChoice"),
    ("rust/src/net/http.rs", "HttpError"),
]

GOLDEN_UNSAFE = "rust/tests/audit_golden/unsafe_inventory.txt"

# Directories (relative to the repo root) whose .rs files are audited.
RS_DIRS = ["rust/src", "rust/tests", "rust/benches", "examples"]
# Path fragments excluded everywhere (fixtures carry seeded violations).
EXCLUDE = ["audit_fixtures", "/target/"]


def finding(code, pass_name, file, line, message):
    return {"code": code, "pass": pass_name, "file": file, "line": line, "message": message}


# -- lexer ------------------------------------------------------------------


def scrub(src):
    """Blank comments and string/char literal bodies, preserving length,
    newlines and delimiters. Returns (scrubbed, error) where error is an
    (line, message) for an unterminated construct, else None."""
    out = []
    chars = list(src)
    n = len(chars)
    i = 0
    line = 1

    def put(c):
        out.append(c)

    def blank(c):
        out.append("\n" if c == "\n" else " ")

    while i < n:
        c = chars[i]
        nxt = chars[i + 1] if i + 1 < n else ""
        if c == "\n":
            line += 1
        if c == "/" and nxt == "/":
            while i < n and chars[i] != "\n":
                blank(chars[i])
                i += 1
            continue
        if c == "/" and nxt == "*":
            start = line
            depth = 0
            while i < n:
                if chars[i] == "\n":
                    line += 1
                if chars[i] == "/" and i + 1 < n and chars[i + 1] == "*":
                    depth += 1
                    blank(chars[i])
                    blank(chars[i + 1])
                    i += 2
                    continue
                if chars[i] == "*" and i + 1 < n and chars[i + 1] == "/":
                    depth -= 1
                    blank(chars[i])
                    blank(chars[i + 1])
                    i += 2
                    if depth == 0:
                        break
                    continue
                blank(chars[i])
                i += 1
            if depth != 0:
                return "".join(out), (start, "unterminated block comment")
            continue
        prev = chars[i - 1] if i > 0 else ""
        prev_is_ident = bool(prev) and (prev.isalnum() or prev == "_")
        # Raw / byte string openers: r" r#" br" br#" b" (never mid-ident).
        if not prev_is_ident and c in ("r", "b"):
            j = i
            if c == "b" and j + 1 < n and chars[j + 1] == "r":
                j += 1
            if chars[j] in ("r", "b") or True:
                pass
            k = j + 1
            hashes = 0
            while k < n and chars[k] == "#" and chars[j] != "b":
                hashes += 1
                k += 1
            raw = chars[j] == "r" or (c == "b" and j > i)
            if k < n and chars[k] == '"' and (raw or (c == "b" and j == i)):
                start = line
                # emit prefix + opening quote
                for p in range(i, k + 1):
                    put(chars[p])
                    if chars[p] == "\n":
                        line += 1
                i = k + 1
                closed = False
                while i < n:
                    if chars[i] == "\n":
                        line += 1
                        put("\n")
                        i += 1
                        continue
                    if not raw and chars[i] == "\\" and i + 1 < n:
                        blank(chars[i])
                        blank(chars[i + 1])
                        if chars[i + 1] == "\n":
                            line += 1
                            out[-1] = "\n"
                        i += 2
                        continue
                    if chars[i] == '"':
                        if raw:
                            h = 0
                            while i + 1 + h < n and chars[i + 1 + h] == "#" and h < hashes:
                                h += 1
                            if h == hashes:
                                put('"')
                                for p in range(h):
                                    put("#")
                                i += 1 + h
                                closed = True
                                break
                            blank(chars[i])
                            i += 1
                            continue
                        put('"')
                        i += 1
                        closed = True
                        break
                    blank(chars[i])
                    i += 1
                if not closed:
                    return "".join(out), (start, "unterminated string literal")
                continue
        if c == '"':
            start = line
            put('"')
            i += 1
            closed = False
            while i < n:
                if chars[i] == "\n":
                    line += 1
                    put("\n")
                    i += 1
                    continue
                if chars[i] == "\\" and i + 1 < n:
                    blank(chars[i])
                    if chars[i + 1] == "\n":
                        line += 1
                        put("\n")
                    else:
                        blank(chars[i + 1])
                    i += 2
                    continue
                if chars[i] == '"':
                    put('"')
                    i += 1
                    closed = True
                    break
                blank(chars[i])
                i += 1
            if not closed:
                return "".join(out), (start, "unterminated string literal")
            continue
        # b'x' byte literals: the `'` is preceded by an ident char (`b`),
        # so allow it through when the char before the `b` is a non-ident.
        byte_char = (
            c == "'" and prev == "b"
            and not (i >= 2 and (chars[i - 2].isalnum() or chars[i - 2] == "_")))
        if c == "'" and (not prev_is_ident or byte_char):
            # Char literal vs lifetime.
            if nxt == "\\":
                put("'")
                i += 1
                blank(chars[i])  # backslash
                i += 1
                # the escaped char itself is never the closer (handles '\'')
                if i < n and chars[i] != "\n":
                    blank(chars[i])
                    i += 1
                start = line
                closed = False
                while i < n:
                    if chars[i] == "'":
                        put("'")
                        i += 1
                        closed = True
                        break
                    if chars[i] == "\n":
                        break
                    blank(chars[i])
                    i += 1
                if not closed:
                    return "".join(out), (start, "unterminated char literal")
                continue
            if i + 2 < n and nxt != "'" and chars[i + 2] == "'":
                put("'")
                blank(nxt)
                put("'")
                i += 3
                continue
            # lifetime — pass through
            put(c)
            i += 1
            continue
        put(c)
        i += 1
    return "".join(out), None


def line_of(text, offset):
    return text.count("\n", 0, offset) + 1


def allow_lines(src, pass_name):
    """Line numbers suppressed for a pass via audit:allow comments."""
    allowed = set()
    for idx, l in enumerate(src.splitlines(), start=1):
        m = re.search(r"audit:allow\(([a-z0-9_,\- ]+)\)", l)
        if m and pass_name in [p.strip() for p in m.group(1).split(",")]:
            allowed.add(idx)
            allowed.add(idx + 1)
    return allowed


# -- A001 bracket balance ---------------------------------------------------


def pass_balance(files):
    out = []
    for rel, src in files.items():
        scrubbed, err = scrub(src)
        if err:
            out.append(finding("A001", "bracket-balance", rel, err[0], err[1]))
            continue
        stack = []
        pairs = {")": "(", "]": "[", "}": "{"}
        ok = True
        line = 1
        for ch in scrubbed:
            if ch == "\n":
                line += 1
            elif ch in "([{":
                stack.append((ch, line))
            elif ch in ")]}":
                if not stack or stack[-1][0] != pairs[ch]:
                    out.append(finding(
                        "A001", "bracket-balance", rel, line,
                        f"unbalanced '{ch}'" + (f" (open '{stack[-1][0]}' from line {stack[-1][1]})" if stack else "")))
                    ok = False
                    break
                stack.pop()
        if ok and stack:
            ch, ln = stack[-1]
            out.append(finding("A001", "bracket-balance", rel, ln, f"unclosed '{ch}'"))
    return out


# -- module tree + A002 use resolution --------------------------------------


class Module:
    def __init__(self, path):
        self.path = path          # e.g. "exec::pool" ("" = crate root)
        self.items = set()        # pub-ish item names (incl. private: we
                                  # audit resolvability, not visibility)
        self.submodules = set()
        self.has_glob_reexport = False
        self.reexport_globs = []  # module paths globbed in via pub use ..::*
        self.parsed = False


ITEM_RE = re.compile(
    r"(?:^|[;{}]\s*|\n\s*)(?:pub(?:\s*\([^)]*\))?\s+)?"
    r"(fn|struct|enum|trait|union|type|const|static|macro_rules!)\s+([A-Za-z_][A-Za-z0-9_]*)")
MOD_DECL_RE = re.compile(r"(?:pub(?:\s*\([^)]*\))?\s+)?mod\s+([A-Za-z_][A-Za-z0-9_]*)\s*([;{])")


def split_use_tree(tree):
    """'a::{b, c as d, e::*}' -> list of (path_segments, leaf_or_star)."""
    tree = tree.strip()
    results = []

    def rec(prefix, t):
        t = t.strip()
        brace = t.find("{")
        if brace == -1:
            segs = [s.strip() for s in t.split("::") if s.strip()]
            alias = None
            if segs and " as " in segs[-1]:
                last, alias = segs[-1].split(" as ", 1)
                segs[-1] = last.strip()
            results.append((prefix + segs, (alias or "").strip() or None))
            return
        head = t[:brace].rstrip()
        if head.endswith("::"):
            head = head[:-2]
        segs = prefix + [s.strip() for s in head.split("::") if s.strip()]
        inner = t[brace + 1:t.rfind("}")]
        depth = 0
        part = ""
        parts = []
        for ch in inner:
            if ch == "{":
                depth += 1
            elif ch == "}":
                depth -= 1
            if ch == "," and depth == 0:
                parts.append(part)
                part = ""
            else:
                part += ch
        if part.strip():
            parts.append(part)
        for p in parts:
            rec(segs, p)

    rec([], tree)
    return results


def parse_modules_in_file(rel, scrubbed, base_modpath, modules, uses):
    """Collect items, submodule decls, and use statements, tracking inline
    `mod x { .. }` nesting so each use knows its module path."""
    # inline module spans: list of (start, end, modpath)
    spans = []

    def walk(seg_start, seg_end, modpath):
        if modpath not in modules:
            modules[modpath] = Module(modpath)
        m = modules[modpath]
        m.parsed = True
        body = scrubbed[seg_start:seg_end]
        # find inline mods at this level; recurse and mask them out
        masked = body
        pos = 0
        while True:
            mm = MOD_DECL_RE.search(masked, pos)
            if not mm:
                break
            name, kind = mm.group(1), mm.group(2)
            child = (modpath + "::" + name).lstrip(":")
            if kind == ";":
                m.submodules.add(name)
                pos = mm.end()
                continue
            # inline: find matching close brace
            depth = 0
            j = seg_start + mm.end() - 1
            while j < seg_end:
                if scrubbed[j] == "{":
                    depth += 1
                elif scrubbed[j] == "}":
                    depth -= 1
                    if depth == 0:
                        break
                j += 1
            m.submodules.add(name)
            walk(seg_start + mm.end(), j, child)
            # mask the inline body so outer item scan skips it
            masked = masked[:mm.end()] + " " * (j - (seg_start + mm.end())) + masked[j - seg_start:]
            pos = j - seg_start
        for im in ITEM_RE.finditer(masked):
            m.items.add(im.group(2))
        # use statements at this level
        for um in re.finditer(r"(?:^|[;{}]\s*|\n\s*)(pub(?:\s*\([^)]*\))?\s+)?use\s+([^;]+);", masked):
            is_pub = bool(um.group(1))
            tree = um.group(2)
            off = seg_start + um.start(2)
            uses.append((rel, modpath, is_pub, tree, line_of(scrubbed, off)))
            if is_pub:
                for segs, alias in split_use_tree(tree):
                    if not segs:
                        continue
                    if segs[-1] == "*":
                        m.has_glob_reexport = True
                        m.reexport_globs.append(segs[:-1])
                    else:
                        m.items.add(alias or segs[-1])

    walk(0, len(scrubbed), base_modpath)


def build_crate(root, files):
    """Parse rust/src into a module map keyed by 'a::b' ('' = crate root).
    Returns (modules, uses)."""
    modules = {}
    uses = []
    src_files = {rel: s for rel, s in files.items() if rel.startswith("rust/src/")}
    for rel, src in sorted(src_files.items()):
        scrubbed, err = scrub(src)
        if err:
            continue  # balance pass reports it
        p = rel[len("rust/src/"):]
        if p == "lib.rs":
            modpath = ""
        elif p == "main.rs":
            modpath = "__main__"
        elif p.endswith("/mod.rs"):
            modpath = p[:-len("/mod.rs")].replace("/", "::")
        else:
            modpath = p[:-3].replace("/", "::")
        parse_modules_in_file(rel, scrubbed, modpath, modules, uses)
    return modules, uses


def resolve_path(modules, start_mod, segs):
    """Resolve segs (already absolute, crate-rooted) to True/False/None.
    None = cannot decide confidently (skip)."""
    cur = ""
    for idx, seg in enumerate(segs):
        last = idx == len(segs) - 1
        m = modules.get(cur)
        if m is None or not m.parsed:
            return None
        if seg == "*":
            return True
        if seg == "self":
            # `use a::b::{self, X}` — refers to the module resolved so far.
            continue
        if seg in m.submodules:
            cur = (cur + "::" + seg).lstrip(":")
            continue
        if seg in m.items:
            # Items may have associated paths (Enum::Variant in a use tree);
            # accept the remainder unchecked.
            return True
        if m.has_glob_reexport:
            return None  # name may come in through the glob
        return False
    return True


def pass_use_resolution(root, files):
    out = []
    modules, uses = build_crate(root, files)

    def check(rel, modpath, tree, lineno, crate_prefixes):
        for segs, _alias in split_use_tree(tree):
            if not segs:
                continue
            head = segs[0]
            if head in ("crate", "pawd") and "crate" in crate_prefixes:
                abs_segs = segs[1:]
            elif head == "self":
                abs_segs = (modpath.split("::") if modpath and modpath != "__main__" else []) + segs[1:]
            elif head == "super":
                parts = modpath.split("::") if modpath and modpath != "__main__" else []
                k = 0
                while k < len(segs) and segs[k] == "super":
                    k += 1
                if k > len(parts):
                    out.append(finding("A002", "use-resolution", rel, lineno,
                                       f"'{'::'.join(segs)}': too many 'super'"))
                    continue
                abs_segs = parts[:len(parts) - k] + segs[k:]
            else:
                continue  # external crate
            r = resolve_path(modules, modpath, abs_segs)
            if r is False:
                out.append(finding("A002", "use-resolution", rel, lineno,
                                   f"use path '{'::'.join(segs)}' does not resolve"))

    # src files: crate:: and super:: / self::
    src_allow = {rel: allow_lines(src, "use-resolution") for rel, src in files.items()}
    for rel, modpath, _is_pub, tree, lineno in uses:
        if lineno in src_allow.get(rel, ()):
            continue
        check(rel, modpath, tree, lineno, crate_prefixes={"crate"})

    # tests/benches/examples: pawd:: resolves against the lib crate root.
    for rel, src in sorted(files.items()):
        if rel.startswith("rust/src/"):
            continue
        scrubbed, err = scrub(src)
        if err:
            continue
        allowed = allow_lines(src, "use-resolution")
        for um in re.finditer(r"(?:^|[;{}]\s*|\n\s*)(?:pub\s+)?use\s+([^;]+);", scrubbed):
            tree = um.group(1)
            lineno = line_of(scrubbed, um.start(1))
            if lineno in allowed:
                continue
            for segs, _alias in split_use_tree(tree):
                if not segs or segs[0] != "pawd":
                    continue
                r = resolve_path(modules, "", segs[1:])
                if r is False:
                    out.append(finding("A002", "use-resolution", rel, lineno,
                                       f"use path '{'::'.join(segs)}' does not resolve"))
    return out


# -- A003 exhaustive matches ------------------------------------------------


def enum_variants(files, enum_file, enum_name):
    src = files.get(enum_file)
    if src is None:
        return None
    scrubbed, err = scrub(src)
    if err:
        return None
    m = re.search(r"enum\s+" + enum_name + r"\b[^{]*\{", scrubbed)
    if not m:
        return None
    i = m.end() - 1
    depth = 0
    start = i
    while i < len(scrubbed):
        if scrubbed[i] == "{":
            depth += 1
        elif scrubbed[i] == "}":
            depth -= 1
            if depth == 0:
                break
        i += 1
    body = scrubbed[start + 1:i]
    variants = []
    j, n, d = 0, len(body), 0
    at_start = True  # expecting the next variant name
    while j < n:
        ch = body[j]
        if d == 0 and ch == "#":
            # skip a variant attribute #[...]
            while j < n and body[j] != "[":
                j += 1
            dd = 0
            while j < n:
                if body[j] == "[":
                    dd += 1
                elif body[j] == "]":
                    dd -= 1
                    if dd == 0:
                        break
                j += 1
            j += 1
            continue
        if ch in "([{":
            d += 1
        elif ch in ")]}":
            d -= 1
        elif d == 0 and ch == ",":
            at_start = True
        elif d == 0 and at_start and (ch.isalpha() or ch == "_"):
            mm = IDENT.match(body, j)
            variants.append(mm.group(0))
            at_start = False
            j = mm.end()
            continue
        j += 1
    return variants


def iter_matches(scrubbed):
    """Yield (offset, arms) for every `match` block; each arm is
    (pattern_text, pattern_offset)."""
    for m in re.finditer(r"\bmatch\b", scrubbed):
        i = m.end()
        depth = 0
        n = len(scrubbed)
        # find block-open brace at bracket depth 0
        while i < n:
            c = scrubbed[i]
            if c in "([":
                depth += 1
            elif c in ")]":
                depth -= 1
            elif c == "{" and depth == 0:
                break
            elif c == ";" and depth == 0:
                i = None
                break
            i += 1
        if i is None or i >= n:
            continue
        block_start = i
        # walk arms at depth 1
        arms = []
        i += 1
        while i < n:
            # skip ws
            while i < n and scrubbed[i] in " \t\n":
                i += 1
            if i >= n or scrubbed[i] == "}":
                break
            pat_start = i
            d = 0
            # pattern: until top-level =>
            while i < n:
                c = scrubbed[i]
                if c in "([{":
                    d += 1
                elif c in ")]}":
                    if d == 0 and c == "}":
                        break  # malformed; bail
                    d -= 1
                elif c == "=" and d == 0 and i + 1 < n and scrubbed[i + 1] == ">":
                    break
                i += 1
            if i >= n or scrubbed[i] == "}":
                break
            arms.append((scrubbed[pat_start:i], pat_start))
            i += 2  # skip =>
            while i < n and scrubbed[i] in " \t\n":
                i += 1
            if i < n and scrubbed[i] == "{":
                d = 0
                while i < n:
                    if scrubbed[i] == "{":
                        d += 1
                    elif scrubbed[i] == "}":
                        d -= 1
                        if d == 0:
                            break
                    i += 1
                i += 1
                while i < n and scrubbed[i] in " \t\n":
                    i += 1
                if i < n and scrubbed[i] == ",":
                    i += 1
            else:
                d = 0
                while i < n:
                    c = scrubbed[i]
                    if c in "([{":
                        d += 1
                    elif c in ")]}":
                        if d == 0:
                            break
                        d -= 1
                    elif c == "," and d == 0:
                        i += 1
                        break
                    i += 1
        yield block_start, arms


def pattern_is_catch_all(pat):
    """A top-level `_`, `..`, or bare binding (no ::, no literal)."""
    p = pat.strip()
    if " if " in p:  # guard: a guarded arm never guarantees coverage
        p = p.split(" if ")[0].strip()
        guarded = True
    else:
        guarded = False
    for alt in p.split("|"):
        a = alt.strip()
        for pre in ("ref ", "mut ", "ref mut "):
            if a.startswith(pre):
                a = a[len(pre):].strip()
        if a == "_" or a == "..":
            if not guarded:
                return True
        if re.fullmatch(r"[a-z_][a-z0-9_]*", a) and a not in ("true", "false"):
            if not guarded:
                return True
    return False


def pass_match_exhaustive(root, files):
    out = []
    enums = {}
    for efile, ename in GROWN_ENUMS:
        v = enum_variants(files, efile, ename)
        if v is None:
            out.append(finding("A003", "match-exhaustive", efile, 1,
                               f"grown enum {ename} not found (audit config stale?)"))
        else:
            enums[ename] = set(v)
    for rel, src in sorted(files.items()):
        if not rel.startswith(("rust/src/", "rust/tests/", "rust/benches/")):
            continue
        scrubbed, err = scrub(src)
        if err:
            continue
        allowed = allow_lines(src, "match-exhaustive")
        for block_start, arms in iter_matches(scrubbed):
            if not arms:
                continue
            lineno = line_of(scrubbed, block_start)
            if lineno in allowed:
                continue
            for ename, declared in enums.items():
                mention = [a for a in arms if re.search(r"\b" + ename + r"\s*::", a[0])]
                if not mention:
                    continue
                # only audit matches where every arm is this enum or catch-all
                shaped = all(
                    re.match(r"^\s*(" + ename + r"|_|[a-z_][a-z0-9_]*)\b", a[0].strip())
                    for a in arms)
                if not shaped or len(mention) != len([a for a in arms if not pattern_is_catch_all(a[0])]):
                    continue
                if any(pattern_is_catch_all(a[0]) for a in arms):
                    continue
                used = set()
                for a in arms:
                    used.update(re.findall(ename + r"\s*::\s*([A-Za-z_][A-Za-z0-9_]*)", a[0]))
                missing = declared - used
                if missing:
                    out.append(finding(
                        "A003", "match-exhaustive", rel, lineno,
                        f"match over {ename} has no catch-all and misses: "
                        + ", ".join(sorted(missing))))
    return out


# -- A101 counter drift -----------------------------------------------------


def counter_getters(files):
    src = files["rust/src/exec/counters.rs"]
    scrubbed, _ = scrub(src)
    names = []
    for m in re.finditer(r"pub fn ([a-z0-9_]+)\(\) -> u64", scrubbed):
        names.append(m.group(1))
    return names


def struct_fields(scrubbed, struct_name):
    m = re.search(r"struct\s+" + struct_name + r"\s*\{", scrubbed)
    if not m:
        return None
    i = m.end() - 1
    depth = 0
    start = i
    while i < len(scrubbed):
        if scrubbed[i] == "{":
            depth += 1
        elif scrubbed[i] == "}":
            depth -= 1
            if depth == 0:
                break
        i += 1
    body = scrubbed[start + 1:i]
    return re.findall(r"pub ([a-z0-9_]+)\s*:", body)


def backticked(text):
    return set(re.findall(r"`([A-Za-z0-9_]+)`", text))


def readme_table(readme, heading_fragment):
    """Rows of the first markdown table after a heading containing the
    fragment; returns set of first-column backticked names, or None."""
    lines = readme.splitlines()
    try:
        h = next(i for i, l in enumerate(lines)
                 if l.startswith("#") and heading_fragment in l)
    except StopIteration:
        return None
    names = set()
    in_table = False
    for l in lines[h + 1:]:
        if l.startswith("#"):
            break
        if l.startswith("|"):
            in_table = True
            m = re.match(r"\|\s*`([A-Za-z0-9_]+)`", l)
            if m:
                names.add(m.group(1))
        elif in_table and not l.strip():
            break
    return names if in_table else None


def pass_counter_drift(root, files):
    out = []
    f = lambda file, line, msg: out.append(finding("A101", "counter-drift", file, line, msg))
    counters = counter_getters(files)
    counters = [c for c in counters if c != "reset"]
    metrics_src = files["rust/src/coordinator/metrics.rs"]
    metrics, _ = scrub(metrics_src)
    fields = struct_fields(metrics, "MetricsSnapshot")
    if fields is None:
        f("rust/src/coordinator/metrics.rs", 1, "MetricsSnapshot struct not found")
        return out
    for c in counters:
        if c not in fields:
            f("rust/src/coordinator/metrics.rs", 1,
              f"counter '{c}' (exec/counters.rs) has no MetricsSnapshot field")
        if not re.search(r"counters::" + c + r"\(\)", metrics):
            f("rust/src/coordinator/metrics.rs", 1,
              f"counter '{c}' is never read into the snapshot (snapshot_inner)")
    wire_src = files["rust/src/net/wire.rs"]
    for field in fields:
        hits = len(re.findall(r'"' + field + r'"', wire_src))
        if hits < 2:
            f("rust/src/net/wire.rs", 1,
              f"MetricsSnapshot field '{field}' missing from the wire codec "
              f"(need both snapshot_to_json and snapshot_from_json)")
    main_src = files["rust/src/main.rs"]
    snap_refs = set()
    for m in re.finditer(r"\bsnap\.([a-z0-9_]+)", main_src):
        snap_refs.add(m.group(1))
        if m.group(1) not in fields:
            f("rust/src/main.rs", line_of(main_src, m.start()),
              f"serve summary references unknown snapshot field '{m.group(1)}'")
    for c in counters:
        if c not in snap_refs:
            f("rust/src/main.rs", 1,
              f"counter '{c}' is not surfaced in any CLI summary line (snap.{c})")
    readme = files["README.md"]
    table = readme_table(readme, "Counter registry")
    if table is None:
        f("README.md", 1, "README counter table ('Counter registry' heading) not found")
        return out
    for c in counters:
        if c not in table:
            f("README.md", 1, f"counter '{c}' missing from the README counter table")
    for name in table:
        if name not in counters:
            f("README.md", 1, f"README counter table lists unknown counter '{name}'")
    return out


# -- A102 env drift ---------------------------------------------------------


def pass_env_drift(root, files):
    out = []
    reads = {}
    for rel, src in sorted(files.items()):
        if not rel.endswith(".rs"):
            continue
        for m in re.finditer(r'env::var(?:_os)?\s*\(\s*"(PAWD_[A-Z0-9_]+)"', src):
            reads.setdefault(m.group(1), (rel, line_of(src, m.start())))
    readme = files["README.md"]
    table = readme_table(readme, "Environment knobs")
    if table is None:
        out.append(finding("A102", "env-drift", "README.md", 1,
                           "README env table ('Environment knobs' heading) not found"))
        return out
    for var, (rel, line) in sorted(reads.items()):
        if var not in table:
            out.append(finding("A102", "env-drift", rel, line,
                               f"env var '{var}' read here but missing from the README env table"))
    for var in sorted(table):
        if var.startswith("PAWD_") and var not in reads:
            out.append(finding("A102", "env-drift", "README.md", 1,
                               f"README env table lists '{var}' but nothing reads it"))
    return out


# -- A103 route drift -------------------------------------------------------


def kebab(name):
    return re.sub(r"(?<!^)([A-Z])", r"-\1", name).lower()


def pass_route_drift(root, files):
    out = []
    f = lambda file, line, msg: out.append(finding("A103", "route-drift", file, line, msg))
    variants = enum_variants(files, "rust/src/coordinator/request.rs", "AdminOp")
    if variants is None:
        f("rust/src/coordinator/request.rs", 1, "AdminOp enum not found")
        return out
    wire_src = files["rust/src/net/wire.rs"]
    m = re.search(r"pub mod admin_routes\s*\{", wire_src)
    if not m:
        f("rust/src/net/wire.rs", 1, "admin_routes module not found")
        return out
    i = m.end() - 1
    depth = 0
    start = i
    while i < len(wire_src):
        if wire_src[i] == "{":
            depth += 1
        elif wire_src[i] == "}":
            depth -= 1
            if depth == 0:
                break
        i += 1
    body = wire_src[start:i]
    consts = dict(re.findall(r'pub const ([A-Z_]+): &str = "([a-z\-]+)";', body))
    all_m = re.search(r"pub const ALL: \[&str; (\d+)\] = \[(.*?)\];", body, re.S)
    if not all_m:
        f("rust/src/net/wire.rs", 1, "admin_routes::ALL not found")
        return out
    all_names = re.findall(r"[A-Z][A-Z_]*", all_m.group(2))
    expect = {kebab(v) for v in variants}
    got = set(consts.values())
    for r in sorted(expect - got):
        f("rust/src/net/wire.rs", 1, f"AdminOp variant route '{r}' has no admin_routes const")
    for r in sorted(got - expect):
        f("rust/src/net/wire.rs", 1, f"admin_routes const '{r}' matches no AdminOp variant")
    if int(all_m.group(1)) != len(variants) or len(all_names) != len(variants):
        f("rust/src/net/wire.rs", 1,
          f"admin_routes::ALL has {len(all_names)} entries (declared {all_m.group(1)}), "
          f"AdminOp has {len(variants)} variants")
    if sorted(set(all_names)) != sorted(consts.keys()):
        f("rust/src/net/wire.rs", 1, "admin_routes::ALL does not list every const exactly once")
    readme = files["README.md"]
    row = next((l for l in readme.splitlines() if "/v1/admin/<op>" in l), None)
    if row is None:
        f("README.md", 1, "README route table has no /v1/admin/<op> row")
        return out
    for r in sorted(got):
        if f"`{r}`" not in row:
            f("README.md", 1, f"README admin route row does not mention `{r}`")
    return out


# -- A104 bench key drift ---------------------------------------------------


def pass_bench_keys(root, files):
    out = []
    try:
        baseline = json.loads(files["BENCH_baseline.json"])
    except (KeyError, json.JSONDecodeError) as e:
        return [finding("A104", "bench-key-drift", "BENCH_baseline.json", 1, f"unreadable: {e}")]
    cargo = files["rust/Cargo.toml"]
    registered = set(re.findall(r'name = "([a-z0-9_]+)"', cargo))
    bench_src = "\n".join(s for rel, s in files.items() if rel.startswith("rust/benches/"))
    for scenario, metrics in sorted(baseline.get("scenarios", {}).items()):
        bench = scenario.split("/")[0]
        if bench not in registered or ("rust/benches/" + bench + ".rs") not in files:
            out.append(finding("A104", "bench-key-drift", "BENCH_baseline.json", 1,
                               f"baseline scenario '{scenario}' names no registered bench"))
            continue
        for metric in sorted(metrics):
            if not metric.endswith("per_s"):
                continue
            if metric in bench_src:
                continue
            pieces = [p for p in re.split(r"[0-9]+", metric) if len(p) > 2]
            if pieces and all(p in bench_src for p in pieces):
                continue
            out.append(finding("A104", "bench-key-drift", "BENCH_baseline.json", 1,
                               f"gated key '{scenario}:{metric}' not emitted by any bench source"))
    return out


# -- A201/A202 unsafe -------------------------------------------------------


def unsafe_sites(rel, src):
    scrubbed, err = scrub(src)
    if err:
        return []
    sites = []
    for m in re.finditer(r"\bunsafe\b", scrubbed):
        after = scrubbed[m.end():m.end() + 40].lstrip()
        if after.startswith("{"):
            kind = "block"
        elif after.startswith("impl"):
            kind = "impl"
        elif after.startswith("fn") or after.startswith("extern"):
            kind = "fn"
        else:
            kind = "block"
        sites.append((line_of(scrubbed, m.start()), kind))
    return sites


def has_safety_comment(lines, lineno, kind):
    """SAFETY on the site line or an immediately-preceding comment/attr/
    unsafe-impl run. For `unsafe fn`, a doc `# Safety` section counts."""
    if "SAFETY" in lines[lineno - 1]:
        return True
    i = lineno - 2
    seen_comment = False
    while i >= 0:
        l = lines[i].strip()
        if l.startswith("//"):
            if "SAFETY" in l or (kind == "fn" and "# Safety" in l):
                return True
            seen_comment = True
            i -= 1
            continue
        if l.startswith("#[") or l.startswith("#!["):
            i -= 1
            continue
        if l.startswith("unsafe impl") or l.startswith("pub unsafe impl"):
            i -= 1
            continue
        if not l:
            if seen_comment:
                break
            i -= 1
            continue
        break
    return False


def pass_unsafe(root, files):
    out = []
    inventory = {}
    for rel, src in sorted(files.items()):
        if not rel.startswith("rust/src/"):
            continue
        sites = unsafe_sites(rel, src)
        if sites:
            inventory[rel] = len(sites)
        lines = src.splitlines()
        allowed = allow_lines(src, "unsafe-safety")
        for lineno, kind in sites:
            if lineno in allowed:
                continue
            if not has_safety_comment(lines, lineno, kind):
                out.append(finding("A201", "unsafe-safety", rel, lineno,
                                   f"unsafe {kind} without a SAFETY comment"))
    golden_path = os.path.join(root, GOLDEN_UNSAFE)
    if not os.path.exists(golden_path):
        out.append(finding("A202", "unsafe-inventory", GOLDEN_UNSAFE, 1,
                           "golden unsafe inventory missing; expected lines '<path> <count>'"))
        return out
    golden = {}
    with open(golden_path) as fh:
        for l in fh:
            l = l.strip()
            if l and not l.startswith("#"):
                p, c = l.rsplit(" ", 1)
                golden[p] = int(c)
    for rel, count in sorted(inventory.items()):
        if golden.get(rel) != count:
            out.append(finding(
                "A202", "unsafe-inventory", rel, 1,
                f"{count} unsafe site(s), golden file says {golden.get(rel, 0)} — "
                f"update {GOLDEN_UNSAFE} if the new unsafe is deliberate"))
    for rel in sorted(set(golden) - set(inventory)):
        out.append(finding("A202", "unsafe-inventory", GOLDEN_UNSAFE, 1,
                           f"golden file lists '{rel}' but it has no unsafe (or is gone)"))
    return out


# -- A203 condvar waits -----------------------------------------------------


def pass_condvar(root, files):
    out = []
    for rel, src in sorted(files.items()):
        if not rel.startswith(("rust/src/", "rust/tests/")):
            continue
        scrubbed, err = scrub(src)
        if err:
            continue
        allowed = allow_lines(src, "condvar-wait-in-loop")
        for m in re.finditer(r"\.wait(?:_timeout)?\s*\(", scrubbed):
            lineno = line_of(scrubbed, m.start())
            if lineno in allowed:
                continue
            # enclosing-brace scan: is any enclosing block a loop/while/for?
            depth = 0
            in_loop = False
            i = m.start()
            opens = []
            d = 0
            for j, ch in enumerate(scrubbed[:i]):
                if ch == "{":
                    opens.append(j)
                elif ch == "}":
                    if opens:
                        opens.pop()
            for open_pos in opens:
                head = scrubbed[max(0, open_pos - 240):open_pos]
                # strip balanced trailing condition text back to a keyword
                cut = max(head.rfind(";"), head.rfind("{"), head.rfind("}"))
                head = head[cut + 1:]
                if re.search(r"\b(loop|while|for)\b", head):
                    in_loop = True
                    break
            if not in_loop:
                out.append(finding(
                    "A203", "condvar-wait-in-loop", rel, lineno,
                    "condvar wait outside any loop — spurious wakeups will "
                    "break the predicate (re-check in a while/loop)"))
    return out


# -- driver -----------------------------------------------------------------


def collect_files(root):
    files = {}
    for d in RS_DIRS:
        base = os.path.join(root, d)
        for dirpath, _dirnames, filenames in os.walk(base):
            for fn in sorted(filenames):
                if not fn.endswith(".rs"):
                    continue
                full = os.path.join(dirpath, fn)
                rel = os.path.relpath(full, root)
                if any(x in rel.replace(os.sep, "/") or x.strip("/") in rel.split(os.sep)
                       for x in EXCLUDE):
                    continue
                with open(full, encoding="utf-8") as fh:
                    files[rel.replace(os.sep, "/")] = fh.read()
    for extra in ["README.md", "BENCH_baseline.json", "rust/Cargo.toml"]:
        p = os.path.join(root, extra)
        if os.path.exists(p):
            with open(p, encoding="utf-8") as fh:
                files[extra] = fh.read()
    return files


def run_audit(root):
    files = collect_files(root)
    findings = []
    findings += pass_balance({r: s for r, s in files.items() if r.endswith(".rs")})
    findings += pass_use_resolution(root, files)
    findings += pass_match_exhaustive(root, files)
    findings += pass_counter_drift(root, files)
    findings += pass_env_drift(root, files)
    findings += pass_route_drift(root, files)
    findings += pass_bench_keys(root, files)
    findings += pass_unsafe(root, files)
    findings += pass_condvar(root, files)
    n_rs = len([r for r in files if r.endswith(".rs")])
    return {"format": 1, "files_scanned": n_rs, "findings": findings}


def find_root(start):
    d = os.path.abspath(start)
    while True:
        if os.path.exists(os.path.join(d, "rust", "Cargo.toml")) and \
           os.path.exists(os.path.join(d, "README.md")):
            return d
        parent = os.path.dirname(d)
        if parent == d:
            return None
        d = parent


def main(argv):
    as_json = "--json" in argv
    argv = [a for a in argv if a != "--json"]
    root = find_root(argv[0] if argv else os.getcwd())
    if root is None:
        print("audit: repo root not found (need rust/Cargo.toml + README.md)", file=sys.stderr)
        return 2
    report = run_audit(root)
    if as_json:
        print(json.dumps(report, indent=2))
    else:
        for f in report["findings"]:
            print(f"{f['code']} [{f['pass']}] {f['file']}:{f['line']}: {f['message']}")
        print(f"audit: {report['files_scanned']} files, {len(report['findings'])} finding(s)")
    return 1 if report["findings"] else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
