#!/usr/bin/env bash
# Diff two BENCH_*.json files and fail on gated regressions (>20% throughput
# drop by default). Thin wrapper over `pawd bench-diff` so CI and local runs
# share one implementation.
#
#   scripts/bench_diff.sh BENCH_baseline.json BENCH_pr.json [--max-regression 0.20] [--promote]
#
# Paths are resolved relative to the caller's working directory.
set -euo pipefail
repo="$(cd "$(dirname "$0")/.." && pwd)"
exec cargo run --manifest-path "$repo/rust/Cargo.toml" --release --quiet --bin pawd -- bench-diff "$@"
