#!/usr/bin/env bash
# Run the repo audit with whatever is available, preferring the Rust
# analyzer (the thing CI gates on) and falling back to the Python mirror
# (same passes, same finding codes) in toolchain-less containers.
#
#   ./scripts/audit.sh [--json]
#
# Exit status: 0 clean, 1 findings, 2 analyzer error.
set -u
cd "$(dirname "$0")/.."

if [ -n "${PAWD_BIN:-}" ] && [ -x "${PAWD_BIN:-}" ]; then
    exec "$PAWD_BIN" audit --root . "$@"
fi
if [ -x rust/target/release/pawd ]; then
    exec rust/target/release/pawd audit --root . "$@"
fi
if command -v cargo >/dev/null 2>&1; then
    # --release: the audit lexes the whole tree; debug builds take
    # noticeably longer than the compile does.
    exec cargo run --quiet --release --manifest-path rust/Cargo.toml -- \
        audit --root . "$@"
fi
if command -v python3 >/dev/null 2>&1; then
    exec python3 scripts/audit.py "$@"
fi
echo "audit.sh: no pawd binary, no cargo, no python3" >&2
exit 2
