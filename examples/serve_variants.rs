//! Multi-variant serving demo — the paper's systems scenario: many
//! task-specialized fine-tunes of one base served from compact deltas,
//! with hot-swap cold starts, an LRU variant cache, and **live updates**
//! through the control plane (publish → query → rollback).
//!
//! Builds N variants on disk, starts the coordinator, replays a skewed
//! request mix from several client threads, then — while traffic is still
//! flowing — publishes a new version of the hot variant, verifies the alias
//! flip, rolls it back, and reports throughput, latency percentiles, cache
//! behaviour and lifecycle counters.
//!
//! ```bash
//! cargo run --release --example serve_variants [n_variants] [n_requests]
//! ```

use pawd::coordinator::{Engine, Payload, Server, ServerConfig, VariantStore};
use pawd::delta::compress::{compress_model, CompressOptions, FitMode};
use pawd::delta::format::save_delta;
use pawd::model::config::ModelConfig;
use pawd::model::synth::{synth_finetune, SynthDeltaSpec};
use pawd::model::FlatParams;
use pawd::util::rng::Rng;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n_variants: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(6);
    let n_requests: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(400);

    // --- build the variant fleet ---
    let cfg = ModelConfig::preset("tiny")?;
    let base = Arc::new(FlatParams::init(&cfg, 11));
    let dir = std::env::temp_dir().join("pawd_serve_variants");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir)?;
    let calib: Vec<Vec<u8>> = (0..4)
        .map(|i| (0..40).map(|t| ((t * 7 + i * 31) % 200 + 20) as u8).collect())
        .collect();
    let opts = CompressOptions { fit: FitMode::ClosedForm, ..Default::default() };
    println!("building {n_variants} compressed variants of {} ...", cfg.name);
    for k in 0..n_variants {
        let ft = synth_finetune(&base, &SynthDeltaSpec { seed: 900 + k as u64, ..Default::default() });
        let (delta, _, _) = compress_model(&format!("task{k}"), &base, &ft, &calib, &opts);
        let bytes = save_delta(dir.join(format!("task{k}.pawd")), &delta)?;
        println!("  task{k}: {} on disk", pawd::util::benchkit::fmt_bytes(bytes));
    }
    // A refreshed fine-tune of the hot variant, staged for live publication.
    // (Staged outside the registry dir — files inside it get adopted.)
    let staging = std::env::temp_dir().join("pawd_serve_variants_staging");
    std::fs::create_dir_all(&staging)?;
    let staged = staging.join("task0_v2.pawd");
    {
        let ft2 = synth_finetune(&base, &SynthDeltaSpec { seed: 9000, ..Default::default() });
        let (delta2, _, _) = compress_model("task0", &base, &ft2, &calib, &opts);
        save_delta(&staged, &delta2)?;
    }

    // --- start the coordinator with a budget that holds ~half the fleet
    // if it were dense; in the default fused mode the same budget holds
    // every variant as packed bytes ---
    let variant_bytes = (base.data.len() * 4) as u64;
    let store = VariantStore::new(base.clone(), &dir);
    let server = Server::start(
        store,
        Engine::Native,
        ServerConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            n_workers: 2,
            cache_budget_bytes: variant_bytes * (n_variants as u64 / 2).max(1) + 1024,
            exec: pawd::exec::ExecMode::Fused,
        },
    );

    // --- replay a zipf-ish request mix from 4 client threads, and run the
    // lifecycle demo from a 5th thread while traffic flows ---
    println!("replaying {n_requests} requests across 4 client threads ...");
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for tid in 0..4 {
            let client = server.client();
            s.spawn(move || {
                let mut rng = Rng::new(tid as u64);
                for i in 0..n_requests / 4 {
                    // Skewed popularity: variant 0 is hot, tail is cold.
                    let v = if rng.chance(0.5) {
                        0
                    } else {
                        rng.below(n_variants)
                    };
                    let rx = client.submit(
                        &format!("task{v}"),
                        Payload::score(
                            &format!("Q: request {i} from {tid}? A: "),
                            &["yes".into(), "no".into(), "maybe".into(), "never".into()],
                        ),
                    );
                    let resp = rx.recv().expect("response");
                    assert!(resp.result.is_ok());
                }
            });
        }
        // --- the control-plane demo: publish task0 v2 mid-traffic, query
        // both versions, then roll back ---
        let admin = server.client();
        let staged = &staged;
        s.spawn(move || {
            let probe = |label: &str| {
                let r = admin.score("task0", "Q: lifecycle probe? A: ", &["yes".into(), "no".into()]);
                println!(
                    "  [{label}] task0 answered by version {:?} (ok={})",
                    r.version,
                    r.result.is_ok()
                );
                r.version
            };
            assert_eq!(probe("pre-publish "), Some(1));
            let v2 = admin.publish("task0", staged).expect("publish");
            println!("  published task0@{v2} (alias flipped, new version warmed)");
            assert_eq!(probe("post-publish"), Some(v2));
            let back = admin.rollback("task0", None).expect("rollback");
            println!("  rolled task0 back to version {back}");
            assert_eq!(probe("post-rollback"), Some(back));
            for d in admin.variants().expect("list") {
                if d.name == "task0" {
                    println!(
                        "  task0 history: active v{}, versions {:?}",
                        d.active,
                        d.versions.iter().map(|v| v.version).collect::<Vec<_>>()
                    );
                }
            }
        });
    });
    let wall = t0.elapsed();

    // --- report ---
    let snap = server.metrics.snapshot();
    let cache = server.cache.stats();
    println!("\n=== serving report ===");
    println!("requests served      : {} in {:.2}s -> {:.1} req/s", snap.served, wall.as_secs_f64(), snap.served as f64 / wall.as_secs_f64());
    println!("errors               : {}", snap.errors);
    println!("batches              : {} (mean size {:.2})", snap.batches, snap.mean_batch_size);
    println!("queue   p50/p99      : {} / {} µs", snap.queue_p50_us, snap.queue_p99_us);
    println!("compute p50/p99      : {} / {} µs", snap.compute_p50_us, snap.compute_p99_us);
    println!("total   p50/p99      : {} / {} µs", snap.total_p50_us, snap.total_p99_us);
    println!("cache hits/misses    : {} / {} ({} evictions)", cache.hits, cache.misses, cache.evictions);
    let cold: Vec<f64> = cache.cold_start.iter().map(|d| d.as_secs_f64() * 1e3).collect();
    if !cold.is_empty() {
        let s = pawd::util::stats::Summary::of(&cold);
        println!("cold-start (ms)      : mean {:.2}  p50 {:.2}  max {:.2}  (n={})", s.mean, s.p50, s.max, s.n);
    }
    let res = server.cache.residency();
    println!(
        "resident versions    : {:?}",
        res.per_version
            .iter()
            .map(|e| format!("{}@{}", e.variant, e.version))
            .collect::<Vec<_>>()
    );
    println!(
        "residency            : {} versions in {} packed ({} dense-equivalent, {:.1}x capacity)",
        res.variants,
        pawd::util::benchkit::fmt_bytes(res.resident_bytes),
        pawd::util::benchkit::fmt_bytes(res.dense_equiv_bytes),
        res.dense_equiv_bytes as f64 / res.resident_bytes.max(1) as f64
    );
    println!("hot swaps            : {}", snap.swaps);
    println!("publishes/rollbacks  : {} / {}", snap.publishes, snap.rollbacks);
    server.shutdown();
    println!("serve_variants OK");
    Ok(())
}
