//! Multi-variant serving demo — the paper's systems scenario: many
//! task-specialized fine-tunes of one base served from compact deltas,
//! with hot-swap cold starts and an LRU variant cache.
//!
//! Builds N variants on disk, starts the coordinator, replays a skewed
//! request mix from several client threads, and reports throughput,
//! latency percentiles, cache behaviour and cold-start times.
//!
//! ```bash
//! cargo run --release --example serve_variants [n_variants] [n_requests]
//! ```

use pawd::coordinator::{Engine, Payload, Server, ServerConfig, VariantStore};
use pawd::delta::compress::{compress_model, CompressOptions, FitMode};
use pawd::delta::format::save_delta;
use pawd::model::config::ModelConfig;
use pawd::model::synth::{synth_finetune, SynthDeltaSpec};
use pawd::model::FlatParams;
use pawd::util::rng::Rng;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n_variants: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(6);
    let n_requests: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(400);

    // --- build the variant fleet ---
    let cfg = ModelConfig::preset("tiny")?;
    let base = Arc::new(FlatParams::init(&cfg, 11));
    let dir = std::env::temp_dir().join("pawd_serve_variants");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir)?;
    let calib: Vec<Vec<u8>> = (0..4)
        .map(|i| (0..40).map(|t| ((t * 7 + i * 31) % 200 + 20) as u8).collect())
        .collect();
    let opts = CompressOptions { fit: FitMode::ClosedForm, ..Default::default() };
    println!("building {n_variants} compressed variants of {} ...", cfg.name);
    for k in 0..n_variants {
        let ft = synth_finetune(&base, &SynthDeltaSpec { seed: 900 + k as u64, ..Default::default() });
        let (delta, _, _) = compress_model(&format!("task{k}"), &base, &ft, &calib, &opts);
        let bytes = save_delta(dir.join(format!("task{k}.pawd")), &delta)?;
        println!("  task{k}: {} on disk", pawd::util::benchkit::fmt_bytes(bytes));
    }

    // --- start the coordinator with a budget that holds ~half the fleet
    // if it were dense; in the default fused mode the same budget holds
    // every variant as packed bytes ---
    let variant_bytes = (base.data.len() * 4) as u64;
    let store = VariantStore::new(base.clone(), &dir);
    let server = Server::start(
        store,
        Engine::Native,
        ServerConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            n_workers: 2,
            cache_budget_bytes: variant_bytes * (n_variants as u64 / 2).max(1) + 1024,
            exec: pawd::exec::ExecMode::Fused,
        },
    );

    // --- replay a zipf-ish request mix from 4 client threads ---
    println!("replaying {n_requests} requests across 4 client threads ...");
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for tid in 0..4 {
            let client = server.client();
            s.spawn(move || {
                let mut rng = Rng::new(tid as u64);
                for i in 0..n_requests / 4 {
                    // Skewed popularity: variant 0 is hot, tail is cold.
                    let v = if rng.chance(0.5) {
                        0
                    } else {
                        rng.below(n_variants)
                    };
                    let rx = client.submit(
                        &format!("task{v}"),
                        Payload::Score {
                            prompt: format!("Q: request {i} from {tid}? A: "),
                            choices: vec!["yes".into(), "no".into(), "maybe".into(), "never".into()],
                        },
                    );
                    let resp = rx.recv().expect("response");
                    assert!(resp.result.is_ok());
                }
            });
        }
    });
    let wall = t0.elapsed();

    // --- report ---
    let snap = server.metrics.snapshot();
    let cache = server.cache.stats();
    println!("\n=== serving report ===");
    println!("requests served      : {} in {:.2}s -> {:.1} req/s", snap.served, wall.as_secs_f64(), snap.served as f64 / wall.as_secs_f64());
    println!("errors               : {}", snap.errors);
    println!("batches              : {} (mean size {:.2})", snap.batches, snap.mean_batch_size);
    println!("queue   p50/p99      : {} / {} µs", snap.queue_p50_us, snap.queue_p99_us);
    println!("compute p50/p99      : {} / {} µs", snap.compute_p50_us, snap.compute_p99_us);
    println!("total   p50/p99      : {} / {} µs", snap.total_p50_us, snap.total_p99_us);
    println!("cache hits/misses    : {} / {} ({} evictions)", cache.hits, cache.misses, cache.evictions);
    let cold: Vec<f64> = cache.cold_start.iter().map(|d| d.as_secs_f64() * 1e3).collect();
    if !cold.is_empty() {
        let s = pawd::util::stats::Summary::of(&cold);
        println!("cold-start (ms)      : mean {:.2}  p50 {:.2}  max {:.2}  (n={})", s.mean, s.p50, s.max, s.n);
    }
    println!("resident variants    : {:?}", server.cache.resident());
    let res = server.cache.residency();
    println!(
        "residency            : {} variants in {} packed ({} dense-equivalent, {:.1}x capacity)",
        res.variants,
        pawd::util::benchkit::fmt_bytes(res.resident_bytes),
        pawd::util::benchkit::fmt_bytes(res.dense_equiv_bytes),
        res.dense_equiv_bytes as f64 / res.resident_bytes.max(1) as f64
    );
    println!("hot swaps            : {}", snap.swaps);
    server.shutdown();
    println!("serve_variants OK");
    Ok(())
}
