//! On-the-fly serving mode (paper §4 future work, implemented): instead of
//! materializing Ŵ at swap time, apply the delta *inside* the GEMM via the
//! fused Pallas kernel — zero switch cost, small per-forward overhead.
//!
//! This example compares, for one projection shape, the two serving modes:
//!   A. materialize-then-GEMM  (delta apply once, then plain matmul)
//!   B. fused delta-GEMM       (AOT Pallas kernel, no dense Ŵ anywhere)
//! and verifies they produce identical results.
//!
//! ```bash
//! make artifacts && cargo run --release --example fused_onthefly
//! ```

use pawd::delta::pack::PackedMask;
use pawd::delta::types::{Axis, DeltaModule};
use pawd::model::{ModelConfig, ModuleId, ProjKind};
use pawd::runtime;
use pawd::tensor::Tensor2;
use pawd::util::rng::Rng;
use std::path::PathBuf;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    anyhow::ensure!(
        artifacts.join("manifest.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    let h = runtime::start(&artifacts)?;

    let cfg = ModelConfig::preset("llama-mini")?;
    let (d_out, d_in) = ProjKind::Up.shape(&cfg); // 688 x 256
    let n = 64; // FUSED_N bucket in aot.py
    let mut rng = Rng::new(3);
    let base: Vec<f32> = (0..d_out * d_in).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let delta: Vec<f32> = (0..d_out * d_in).map(|_| rng.normal_f32(0.0, 0.05)).collect();
    let mask = PackedMask::pack(&delta, d_out, d_in);
    let scales: Vec<f32> = (0..d_out).map(|_| rng.uniform_in(0.01, 0.1)).collect();
    let x: Vec<f32> = (0..n * d_in).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let module = DeltaModule {
        id: ModuleId { layer: 0, kind: ProjKind::Up },
        mask: mask.clone(),
        axis: Axis::Row,
        scales: scales.clone(),
    };

    // Mode A: materialize once, then GEMM.
    let t0 = Instant::now();
    let mut w = vec![0f32; base.len()];
    pawd::delta::apply::apply_module_into(&base, &mut w, &module);
    let apply_time = t0.elapsed();
    let xt = Tensor2::from_vec(n, d_in, x.clone());
    let wt = Tensor2::from_vec(d_out, d_in, w);
    let t1 = Instant::now();
    let y_a = xt.matmul_bt(&wt);
    let gemm_time = t1.elapsed();

    // Mode B: fused delta-GEMM through the Pallas artifact (interpret-mode
    // on CPU; on a real TPU this is the MXU path with packed masks in HBM).
    let t2 = Instant::now();
    let y_b = runtime::api::fused_delta_matmul_xla(
        &h, "row", &x, n, &base, d_out, d_in, &mask.words, &scales,
    )?;
    let fused_time = t2.elapsed();

    let mut worst = 0f32;
    for (a, b) in y_a.data.iter().zip(&y_b) {
        worst = worst.max((a - b).abs());
    }
    println!("shape x[{n},{d_in}] · W[{d_out},{d_in}]ᵀ");
    println!("mode A  apply {apply_time:?} + gemm {gemm_time:?}");
    println!("mode B  fused {fused_time:?} (includes PJRT transfer; amortizes at serving batch sizes)");
    println!("max |A - B| = {worst:e}");
    anyhow::ensure!(worst < 1e-3, "modes disagree");

    // Storage story: what each mode keeps resident per variant.
    let dense = (d_out * d_in * 4) as u64;
    let packed = mask.n_bytes() + (scales.len() * 2) as u64;
    println!(
        "resident per variant for this module: mode A {} vs mode B {} ({:.1}x less)",
        pawd::util::benchkit::fmt_bytes(dense),
        pawd::util::benchkit::fmt_bytes(packed),
        dense as f64 / packed as f64
    );
    h.shutdown();
    println!("fused_onthefly OK");
    Ok(())
}
