//! Quickstart: compress a fine-tune into a 1-bit per-axis delta, save it,
//! hot-swap it back onto the base, and check behavioural fidelity.
//!
//! Runs in seconds on the `tiny` preset with no AOT artifacts required:
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use pawd::delta::compress::{compress_model, CompressOptions, FitMode};
use pawd::delta::format::{load_delta, save_delta};
use pawd::eval::fidelity::fidelity;
use pawd::model::config::ModelConfig;
use pawd::model::synth::{synth_finetune, SynthDeltaSpec};
use pawd::model::{FlatParams, Transformer};
use pawd::util::benchkit::fmt_bytes;

fn main() -> anyhow::Result<()> {
    // 1. A base model and a "fine-tune" of it (here synthesized with
    //    anisotropic per-row delta structure; the full pipeline in
    //    examples/train_and_compress.rs *trains* real pairs).
    let cfg = ModelConfig::preset("tiny")?;
    let base = FlatParams::init(&cfg, 42);
    let finetuned = synth_finetune(
        &base,
        &SynthDeltaSpec { magnitude: 0.03, anisotropy: 1.2, axis_bias: 0.7, seed: 7 },
    );
    println!("model: {} ({} params)", cfg.name, cfg.n_params());

    // 2. Calibration documents (stand-in for the paper's 50 C4 samples).
    let calib: Vec<Vec<u8>> = (0..8)
        .map(|i| (0..48).map(|t| ((t * 7 + i * 31) % 200 + 20) as u8).collect())
        .collect();

    // 3. Compress: 1-bit sign masks + learned per-row/col scales, axis
    //    chosen per module by held-out validation MSE (Alg. 6).
    let opts = CompressOptions { fit: FitMode::ClosedForm, ..Default::default() };
    let (delta, reports, _student) = compress_model("demo-ft", &base, &finetuned, &calib, &opts);
    let row = reports.iter().filter(|r| r.chosen == pawd::delta::Axis::Row).count();
    println!("compressed {} modules ({} chose row, {} col)", reports.len(), row, reports.len() - row);

    // 4. Save + reload the PAWD artifact; compare sizes against FP16.
    let dir = std::env::temp_dir().join("pawd_quickstart");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("demo-ft.pawd");
    let bytes = save_delta(&path, &delta)?;
    let fp16 = finetuned.fp16_bytes();
    println!(
        "artifact: {} vs FP16 checkpoint {} -> {:.2}x smaller",
        fmt_bytes(bytes),
        fmt_bytes(fp16),
        fp16 as f64 / bytes as f64
    );

    // 5. Hot-swap: one read, one fused apply per module.
    let loaded = load_delta(&path)?;
    let t0 = std::time::Instant::now();
    let student = pawd::delta::apply::materialize(&base, &loaded.modules);
    println!("hot-swap (clone base + apply {} modules): {:?}", loaded.modules.len(), t0.elapsed());

    // 6. Fidelity: the reconstructed student must track the fine-tune far
    //    better than the raw base does.
    let tf = Transformer::new(&cfg);
    let probes: Vec<Vec<u8>> =
        (0..4).map(|i| (0..48).map(|t| ((t * 13 + i * 53) % 200 + 20) as u8).collect()).collect();
    let f_base = fidelity(&tf, &finetuned, &base, &probes);
    let f_student = fidelity(&tf, &finetuned, &student, &probes);
    println!(
        "teacher-fidelity   KL: base {:.4} -> student {:.4}   argmax agreement: {:.1}% -> {:.1}%",
        f_base.kl,
        f_student.kl,
        f_base.agreement * 100.0,
        f_student.agreement * 100.0
    );
    assert!(f_student.kl < f_base.kl);
    println!("quickstart OK");
    Ok(())
}
