//! The end-to-end system driver (DESIGN.md "End-to-end validation"):
//!
//! 1. pre-train a base LM on the synthetic corpus via the **AOT train-step
//!    artifact** executed from Rust through PJRT (loss curve logged),
//! 2. fine-tune it on the instruct mixture -> the teacher,
//! 3. run the full compression pipeline (per-layer caches -> AdamW scale
//!    fitting -> row/col selection -> end-to-end joint vector training),
//!    for both the paper's method and the BitDelta scalar baseline,
//! 4. write PAWD artifacts + the FP16 teacher checkpoint,
//! 5. evaluate base / teacher / both students on the five zero-shot suites
//!    and print a Table-1-shaped summary plus Table-2-shaped sizes.
//!
//! ```bash
//! make artifacts && cargo run --release --example train_and_compress [config]
//! ```
//! `config` defaults to `llama-mini`; use `tiny` for a fast smoke.

use pawd::baselines;
use pawd::data::tasks::TaskFamily;
use pawd::delta::compress::CompressOptions;
use pawd::pipeline::{run_pair, PairConfig};
use pawd::util::benchkit::{fmt_bytes, Table};
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let config = std::env::args().nth(1).unwrap_or_else(|| "llama-mini".to_string());
    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    anyhow::ensure!(
        artifacts.join("manifest.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    let h = pawd::runtime::start(&artifacts)?;
    let pc = if std::env::var("PAWD_FULL").is_ok() {
        PairConfig::full(&config)
    } else {
        PairConfig::quick(&config)
    };
    let methods = vec![
        ("BitDelta (scalar)", baselines::bitdelta_options(), false),
        ("Vector (row/col)", baselines::vector_options(), true),
    ];
    let out_dir = std::env::temp_dir().join("pawd_train_and_compress").join(&config);
    let t0 = std::time::Instant::now();
    let res = run_pair(&h, &pc, &methods, &out_dir, |m| println!("{m}"))?;

    // Loss curves (downsampled).
    println!("\n--- base pre-training loss curve ({} steps) ---", res.base_losses.len());
    print_curve(&res.base_losses);
    println!("--- fine-tuning loss curve ({} steps) ---", res.finetune_losses.len());
    print_curve(&res.finetune_losses);

    // Table-1-shaped accuracy summary.
    let mut t = Table::new(&["Method", "ARC-C*", "ARC-E*", "HellaSwag*", "PIQA*", "Winogrande*", "Avg"]);
    let mut add = |suite: &pawd::eval::harness::SuiteResult| {
        let mut row = vec![suite.label.clone()];
        for fam in TaskFamily::ALL {
            row.push(format!("{:.2}", suite.pct(fam)));
        }
        row.push(format!("{:.2}", suite.average() * 100.0));
        t.row(&row);
    };
    add(&res.base_suite);
    add(&res.baseline_suite);
    for m in &res.methods {
        add(&m.suite);
    }
    t.print(&format!("Zero-shot accuracy (%) — {} pair", res.config.name));

    // Table-2-shaped sizes.
    let mut t2 = Table::new(&["Artifact", "Size", "vs FP16"]);
    t2.row(&["FP16 teacher".into(), fmt_bytes(res.fp16_bytes), "1.00x".into()]);
    for m in &res.methods {
        t2.row(&[
            m.method.clone(),
            fmt_bytes(m.artifact_bytes),
            format!("{:.2}x smaller", res.fp16_bytes as f64 / m.artifact_bytes as f64),
        ]);
    }
    t2.print("Checkpoint sizes");

    println!("artifacts in {}", out_dir.display());
    println!("total wall time: {:.1}s", t0.elapsed().as_secs_f64());
    h.shutdown();
    Ok(())
}

fn print_curve(losses: &[f32]) {
    let n = losses.len();
    let stride = (n / 10).max(1);
    for (i, chunk) in losses.chunks(stride).enumerate() {
        let mean: f32 = chunk.iter().sum::<f32>() / chunk.len() as f32;
        println!("  step {:>4}: loss {:.4}", i * stride, mean);
    }
}
