"""Pallas kernels vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes (including non-multiples of the 32-bit word size
and degenerate dims) and value distributions; fixed regression cases cover
the exact weight shapes shipped in the AOT manifest.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.delta_apply import delta_apply, vmem_bytes_per_step
from compile.kernels.fused_matmul import fused_delta_matmul, mxu_utilization_estimate

DIMS = st.integers(min_value=1, max_value=97)


def mk(rng, d_out, d_in, axis):
    base = jnp.asarray(rng.normal(size=(d_out, d_in)), jnp.float32)
    delta = jnp.asarray(rng.normal(size=(d_out, d_in)), jnp.float32)
    packed = ref.pack_signs(delta)
    n = d_out if axis == "row" else d_in
    scales = jnp.asarray(rng.uniform(0.001, 0.5, size=(n,)), jnp.float32)
    return base, delta, packed, scales


# ---------------------------------------------------------------------------
# pack/unpack
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(d_out=DIMS, d_in=DIMS, seed=st.integers(0, 2**31 - 1))
def test_pack_unpack_roundtrip(d_out, d_in, seed):
    rng = np.random.default_rng(seed)
    delta = jnp.asarray(rng.normal(size=(d_out, d_in)), jnp.float32)
    packed = ref.pack_signs(delta)
    assert packed.shape == (d_out, ref.words_per_row(d_in))
    signs = ref.unpack_signs(packed, d_in)
    want = np.where(np.asarray(delta) >= 0, 1.0, -1.0)
    np.testing.assert_array_equal(np.asarray(signs), want)


def test_pack_zero_maps_to_plus_one():
    delta = jnp.asarray([[0.0, -0.0, 1.0, -1.0]], jnp.float32)
    signs = ref.unpack_signs(ref.pack_signs(delta), 4)
    np.testing.assert_array_equal(np.asarray(signs), [[1.0, 1.0, 1.0, -1.0]])


def test_pack_bit_layout_matches_rust_convention():
    # bit i of word w == sign of column 32*w + i; first column -> LSB.
    delta = jnp.zeros((1, 33), jnp.float32).at[0, 0].set(-1.0).at[0, 32].set(-1.0)
    packed = np.asarray(ref.pack_signs(delta))
    assert packed.shape == (1, 2)
    assert packed[0, 0] & 1 == 0  # column 0 negative -> bit clear
    assert packed[0, 0] >> 1 == (1 << 31) - 1  # columns 1..31 positive
    assert packed[0, 1] & 1 == 0  # column 32 negative


# ---------------------------------------------------------------------------
# delta_apply kernel
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(d_out=DIMS, d_in=DIMS, axis=st.sampled_from(["row", "col"]), seed=st.integers(0, 2**31 - 1))
def test_delta_apply_matches_ref(d_out, d_in, axis, seed):
    rng = np.random.default_rng(seed)
    base, _, packed, scales = mk(rng, d_out, d_in, axis)
    want = ref.delta_apply_ref(base, packed, scales, axis)
    got = delta_apply(base, packed, scales, axis=axis)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("d_out,d_in", [(256, 256), (688, 256), (256, 688), (64, 64), (128, 64), (64, 128)])
@pytest.mark.parametrize("axis", ["row", "col"])
def test_delta_apply_manifest_shapes(d_out, d_in, axis):
    rng = np.random.default_rng(d_out * 7 + d_in)
    base, _, packed, scales = mk(rng, d_out, d_in, axis)
    want = ref.delta_apply_ref(base, packed, scales, axis)
    got = delta_apply(base, packed, scales, axis=axis)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    d_out=st.integers(2, 32).map(lambda k: 2 * k),
    d_in=DIMS,
    block=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_delta_apply_block_invariance(d_out, d_in, block, seed):
    # The result must not depend on the grid block size.
    rng = np.random.default_rng(seed)
    base, _, packed, scales = mk(rng, d_out, d_in, "row")
    a = delta_apply(base, packed, scales, axis="row", block_rows=block)
    b = delta_apply(base, packed, scales, axis="row", block_rows=d_out)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_delta_apply_rejects_bad_axis():
    rng = np.random.default_rng(0)
    base, _, packed, scales = mk(rng, 4, 8, "row")
    with pytest.raises(ValueError):
        delta_apply(base, packed, scales, axis="diag")


# ---------------------------------------------------------------------------
# fused delta-GEMM kernel
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 32),
    d_out=DIMS,
    d_in=DIMS,
    axis=st.sampled_from(["row", "col"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_matmul_matches_ref(n, d_out, d_in, axis, seed):
    rng = np.random.default_rng(seed)
    base, _, packed, scales = mk(rng, d_out, d_in, axis)
    x = jnp.asarray(rng.normal(size=(n, d_in)), jnp.float32)
    want = ref.fused_delta_matmul_ref(x, base, packed, scales, axis)
    got = fused_delta_matmul(x, base, packed, scales, axis=axis)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_fused_matmul_equals_apply_then_gemm():
    # The fused kernel must equal materialize-then-GEMM numerically.
    rng = np.random.default_rng(5)
    base, _, packed, scales = mk(rng, 64, 96, "row")
    x = jnp.asarray(rng.normal(size=(16, 96)), jnp.float32)
    w = delta_apply(base, packed, scales, axis="row")
    want = x @ w.T
    got = fused_delta_matmul(x, base, packed, scales, axis="row")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# structural perf model sanity
# ---------------------------------------------------------------------------


def test_vmem_footprint_fits_tpu_budget():
    # Largest patchable shape across presets must fit a 16 MiB VMEM budget.
    for (d_out, d_in) in [(256, 256), (688, 256), (256, 688), (1280, 320), (3072, 768)]:
        assert vmem_bytes_per_step(d_out, d_in) < 16 * 1024 * 1024


def test_mxu_estimate_in_unit_range():
    for args in [(64, 256, 256), (64, 688, 256), (8, 64, 64)]:
        u = mxu_utilization_estimate(*args)
        assert 0.0 < u <= 1.0
