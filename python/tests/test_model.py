"""L2 JAX model tests: shapes, causality, training, the logit-matching
gradient, and the layout contract shared with the Rust side."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.PRESETS["tiny"]


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, 0)


def toks(rng, b, t):
    return jnp.asarray(rng.integers(0, CFG.vocab, size=(b, t)), jnp.int32)


def test_param_count_matches_layout():
    lay = M.layout_offsets(CFG)
    assert lay["total"] == CFG.n_params()
    # Layer offsets strictly increasing and disjoint.
    prev = lay["embed"]
    for lo in lay["layers"]:
        for key in ["attn_norm", "wq", "wk", "wv", "wo", "mlp_norm", "w_gate", "w_up", "w_down"]:
            assert lo[key] >= prev
            prev = lo[key]


def test_forward_shape_and_finite(params):
    rng = np.random.default_rng(0)
    logits = M.jit_forward(CFG)(params, toks(rng, 2, 12))
    assert logits.shape == (2, 12, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_causality(params):
    rng = np.random.default_rng(1)
    a = np.asarray(toks(rng, 1, 10))
    b = a.copy()
    b[0, 7:] = (b[0, 7:] + 13) % CFG.vocab
    fwd = M.jit_forward(CFG)
    la = np.asarray(fwd(params, jnp.asarray(a)))
    lb = np.asarray(fwd(params, jnp.asarray(b)))
    np.testing.assert_allclose(la[0, :7], lb[0, :7], atol=1e-5)
    assert np.abs(la[0, 7:] - lb[0, 7:]).max() > 1e-4


def test_batch_independence(params):
    rng = np.random.default_rng(2)
    t1 = toks(rng, 1, 8)
    t2 = toks(rng, 1, 8)
    both = jnp.concatenate([t1, t2], axis=0)
    fwd = M.jit_forward(CFG)
    la = fwd(params, both)
    l1 = fwd(params, t1)
    np.testing.assert_allclose(np.asarray(la[0]), np.asarray(l1[0]), rtol=2e-5, atol=2e-5)


def test_train_step_reduces_loss(params):
    rng = np.random.default_rng(3)
    tp = toks(rng, 4, 17)
    p = params
    m = jnp.zeros_like(p)
    v = jnp.zeros_like(p)
    step = jnp.int32(0)
    ts = M.jit_train_step(CFG)
    losses = []
    for _ in range(25):
        p, m, v, step, loss = ts(p, m, v, step, jnp.float32(3e-3), tp)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7
    assert int(step) == 25


def test_train_step_loss_is_lm_loss(params):
    rng = np.random.default_rng(4)
    tp = toks(rng, 2, 9)
    _, _, _, _, loss = M.jit_train_step(CFG)(
        params, jnp.zeros_like(params), jnp.zeros_like(params), jnp.int32(0), jnp.float32(0.0), tp
    )
    direct = M.lm_loss(CFG, params, tp)
    np.testing.assert_allclose(float(loss), float(direct), rtol=1e-5)


def test_logit_match_grad_zero_at_teacher(params):
    rng = np.random.default_rng(5)
    t = toks(rng, 1, 8)
    teacher_logits = M.jit_forward(CFG)(params, t)
    loss, g = M.jit_logit_match_grad(CFG)(params, t, teacher_logits)
    assert float(loss) < 1e-10
    assert float(jnp.max(jnp.abs(g))) < 1e-4


def test_logit_match_grad_descends(params):
    rng = np.random.default_rng(6)
    t = toks(rng, 2, 10)
    teacher = M.init_params(CFG, 9)
    teacher_logits = M.jit_forward(CFG)(teacher, t)
    lm = M.jit_logit_match_grad(CFG)
    p = params
    loss0, g = lm(p, t, teacher_logits)
    p = p - 0.05 * g
    loss1, _ = lm(p, t, teacher_logits)
    assert float(loss1) < float(loss0)


def test_rope_preserves_norm():
    cos, sin = M.rope_tables(CFG, 16)
    x = jnp.asarray(np.random.default_rng(7).normal(size=(1, 16, 2, CFG.head_dim)), jnp.float32)
    y = M.apply_rope(x, cos[None, :, None, :], sin[None, :, None, :])
    nx = np.linalg.norm(np.asarray(x), axis=-1)
    ny = np.linalg.norm(np.asarray(y), axis=-1)
    np.testing.assert_allclose(nx, ny, rtol=1e-5)


def test_rmsnorm_matches_definition():
    x = jnp.asarray([[3.0, 4.0]], jnp.float32)
    w = jnp.ones((2,), jnp.float32)
    got = np.asarray(M.rmsnorm(x, w))[0]
    inv = 1.0 / np.sqrt(12.5 + M.RMS_EPS)
    np.testing.assert_allclose(got, [3 * inv, 4 * inv], rtol=1e-6)


def test_presets_match_rust_table():
    # Config constants shared with rust/src/model/config.rs.
    want = {
        "tiny": (256, 64, 2, 2, 128, 64),
        "llama-mini": (256, 256, 4, 4, 688, 128),
        "qwen-mini": (256, 320, 5, 5, 1280, 128),
        "phi-mini": (256, 288, 6, 6, 864, 128),
        "base-110m": (256, 768, 12, 12, 3072, 256),
    }
    for name, (v, d, l, h, f, s) in want.items():
        c = M.PRESETS[name]
        assert (c.vocab, c.dim, c.n_layers, c.n_heads, c.ff, c.max_seq) == (v, d, l, h, f, s)
