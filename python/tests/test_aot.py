"""AOT export tests: HLO text round-trips through the XLA client used by
the Rust runtime, and the manifest is consistent with the programs."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model as M


@pytest.fixture(scope="module")
def export_dir(tmp_path_factory):
    d = tmp_path_factory.mktemp("artifacts")
    ex = aot.Exporter(str(d))
    aot.export_config(ex, M.PRESETS["tiny"])
    aot.export_kernels(ex, M.PRESETS["tiny"])
    aot.export_parity_fixture(ex, M.PRESETS["tiny"], 4, 48)
    ex.save_manifest()
    return str(d)


def test_manifest_lists_all_files(export_dir):
    man = json.load(open(os.path.join(export_dir, "manifest.json")))
    assert "tiny" in man["configs"]
    assert man["configs"]["tiny"]["n_params"] == M.PRESETS["tiny"].n_params()
    for name, prog in man["programs"].items():
        path = os.path.join(export_dir, prog["file"])
        assert os.path.exists(path), f"{name} missing file"
        if prog["file"].endswith(".hlo.txt"):
            text = open(path).read()
            assert "HloModule" in text, f"{name} is not HLO text"


def test_hlo_text_parses_back(export_dir):
    """The exported HLO text must parse back through the XLA HLO parser
    (the same parser the rust `xla` crate invokes via
    `HloModuleProto::from_text_file`). The numeric round-trip executes in
    rust/tests/integration_runtime.rs against the parity fixture."""
    man = json.load(open(os.path.join(export_dir, "manifest.json")))
    for name in ["fwd_tiny_b1_t48", "train_tiny_b8_t48", "dapply_row_64x64"]:
        prog = man["programs"][name]
        text = open(os.path.join(export_dir, prog["file"])).read()
        mod = xc._xla.hlo_module_from_text(text)
        assert mod is not None
        # Entry computation must exist and declare the manifest's arity.
        assert text.count("ENTRY") == 1
        # Each manifest input appears as a parameter(k) instruction.
        for k in range(len(prog["inputs"])):
            assert f"parameter({k})" in text, (name, k)


def test_parity_fixture_layout(export_dir):
    cfg = M.PRESETS["tiny"]
    raw = open(os.path.join(export_dir, "parity_tiny.bin"), "rb").read()
    off = 0
    (p,) = np.frombuffer(raw, np.uint32, 1, off)
    off += 4
    assert p == cfg.n_params()
    params = np.frombuffer(raw, np.float32, p, off)
    off += 4 * p
    b, t = np.frombuffer(raw, np.uint32, 2, off)
    off += 8
    tokens = np.frombuffer(raw, np.int32, b * t, off).reshape(b, t)
    off += 4 * b * t
    (v,) = np.frombuffer(raw, np.uint32, 1, off)
    off += 4
    logits = np.frombuffer(raw, np.float32, b * t * v, off).reshape(b, t, v)
    off += 4 * b * t * v
    assert off == len(raw)
    # The stored logits must equal a fresh forward.
    want = np.asarray(M.jit_forward(cfg)(jnp.asarray(params), jnp.asarray(tokens)))
    np.testing.assert_allclose(logits, want, rtol=1e-5, atol=1e-5)


def test_kernel_artifact_names_cover_patchable_shapes(export_dir):
    man = json.load(open(os.path.join(export_dir, "manifest.json")))
    cfg = M.PRESETS["tiny"]
    for (d_out, d_in) in aot.patchable_shapes(cfg):
        for axis in ("row", "col"):
            assert f"dapply_{axis}_{d_out}x{d_in}" in man["programs"]
            assert f"dmm_{axis}_n{aot.FUSED_N}_{d_out}x{d_in}" in man["programs"]
