"""L2: JAX transformer (decoder-only, pre-RMSNorm, RoPE, SwiGLU) over a
single flat parameter vector, plus the fused-AdamW train step and the
logit-matching gradient program.

The flat layout mirrors ``rust/src/model/params.rs`` exactly::

    embed [V,D] | per layer: attn_norm [D] | wq wk wv wo [D,D] |
    mlp_norm [D] | w_gate w_up [F,D] | w_down [D,F] | final_norm [D] |
    lm_head [V,D]

and every op (RMSNorm eps, RoPE convention, attention scaling, SiLU) matches
the native Rust forward pass operation-for-operation — the Rust side is the
parity oracle in ``rust/tests/integration_runtime.rs``.

Python here is build-time only: these functions are AOT-lowered to HLO text
by ``aot.py`` and executed from Rust via PJRT.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

RMS_EPS = 1e-5
ROPE_BASE = 10_000.0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    dim: int
    n_layers: int
    n_heads: int
    ff: int
    max_seq: int

    @property
    def head_dim(self) -> int:
        assert self.dim % self.n_heads == 0
        return self.dim // self.n_heads

    def n_params(self) -> int:
        d, f, v = self.dim, self.ff, self.vocab
        return v * d + self.n_layers * (d + 4 * d * d + d + 2 * f * d + d * f) + d + v * d


# Must stay in sync with rust/src/model/config.rs presets.
PRESETS = {
    "tiny": ModelConfig("tiny", 256, 64, 2, 2, 128, 64),
    "llama-mini": ModelConfig("llama-mini", 256, 256, 4, 4, 688, 128),
    "qwen-mini": ModelConfig("qwen-mini", 256, 320, 5, 5, 1280, 128),
    "phi-mini": ModelConfig("phi-mini", 256, 288, 6, 6, 864, 128),
    "base-110m": ModelConfig("base-110m", 256, 768, 12, 12, 3072, 256),
}


def layout_offsets(cfg: ModelConfig):
    """Offsets of each tensor in the flat vector (mirrors Layout::new)."""
    d, f, v = cfg.dim, cfg.ff, cfg.vocab
    off = 0

    def take(n):
        nonlocal off
        o = off
        off += n
        return o

    out = {"embed": take(v * d), "layers": []}
    for _ in range(cfg.n_layers):
        out["layers"].append(
            {
                "attn_norm": take(d),
                "wq": take(d * d),
                "wk": take(d * d),
                "wv": take(d * d),
                "wo": take(d * d),
                "mlp_norm": take(d),
                "w_gate": take(f * d),
                "w_up": take(f * d),
                "w_down": take(d * f),
            }
        )
    out["final_norm"] = take(d)
    out["lm_head"] = take(v * d)
    out["total"] = off
    assert off == cfg.n_params()
    return out


def _slice2(params, off, rows, cols):
    return jax.lax.dynamic_slice(params, (off,), (rows * cols,)).reshape(rows, cols)


def _slice1(params, off, n):
    return jax.lax.dynamic_slice(params, (off,), (n,))


def rmsnorm(x, w):
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + RMS_EPS) * w


def rope_tables(cfg: ModelConfig, t_len: int):
    hd = cfg.head_dim
    half = hd // 2
    inv_freq = ROPE_BASE ** (-2.0 * jnp.arange(half, dtype=jnp.float32) / hd)
    ang = jnp.arange(t_len, dtype=jnp.float32)[:, None] * inv_freq[None, :]  # [T, half]
    cos = jnp.concatenate([jnp.cos(ang), jnp.cos(ang)], axis=-1)  # [T, hd]
    sin = jnp.concatenate([jnp.sin(ang), jnp.sin(ang)], axis=-1)
    return cos, sin


def apply_rope(x, cos, sin):
    """x: [..., T, heads, hd]; rotate-half convention."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    rotated = jnp.concatenate([-x2, x1], axis=-1)
    return x * cos + rotated * sin


def forward(cfg: ModelConfig, params, tokens):
    """tokens: [B, T] int32 -> logits [B, T, V]."""
    lay = layout_offsets(cfg)
    b, t = tokens.shape
    d, nh, hd = cfg.dim, cfg.n_heads, cfg.head_dim
    embed = _slice2(params, lay["embed"], cfg.vocab, d)
    x = embed[tokens]  # [B, T, D]
    cos, sin = rope_tables(cfg, t)
    causal = jnp.tril(jnp.ones((t, t), dtype=bool))
    for lo in lay["layers"]:
        # --- attention ---
        h = rmsnorm(x, _slice1(params, lo["attn_norm"], d))
        wq = _slice2(params, lo["wq"], d, d)
        wk = _slice2(params, lo["wk"], d, d)
        wv = _slice2(params, lo["wv"], d, d)
        wo = _slice2(params, lo["wo"], d, d)
        q = (h @ wq.T).reshape(b, t, nh, hd)
        k = (h @ wk.T).reshape(b, t, nh, hd)
        v = (h @ wv.T).reshape(b, t, nh, hd)
        q = apply_rope(q, cos[None, :, None, :], sin[None, :, None, :])
        k = apply_rope(k, cos[None, :, None, :], sin[None, :, None, :])
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(jnp.float32(hd))
        neg = jnp.asarray(-1e30, dtype=scores.dtype)
        scores = jnp.where(causal[None, None, :, :], scores, neg)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(b, t, d)
        x = x + ctx @ wo.T
        # --- MLP ---
        h = rmsnorm(x, _slice1(params, lo["mlp_norm"], d))
        w_gate = _slice2(params, lo["w_gate"], cfg.ff, d)
        w_up = _slice2(params, lo["w_up"], cfg.ff, d)
        w_down = _slice2(params, lo["w_down"], d, cfg.ff)
        gate = h @ w_gate.T
        up = h @ w_up.T
        x = x + (jax.nn.silu(gate) * up) @ w_down.T
    x = rmsnorm(x, _slice1(params, lay["final_norm"], d))
    lm = _slice2(params, lay["lm_head"], cfg.vocab, d)
    return x @ lm.T


def lm_loss(cfg: ModelConfig, params, tokens_plus):
    """Causal-LM cross entropy. tokens_plus: [B, T+1] int32."""
    inputs = tokens_plus[:, :-1]
    targets = tokens_plus[:, 1:]
    logits = forward(cfg, params, inputs)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def train_step(cfg: ModelConfig, params, m, v, step, lr, tokens_plus):
    """One fused AdamW step on the LM loss.

    (params, m, v, step i32[], lr f32[], tokens [B, T+1] i32)
    -> (params', m', v', step+1, loss)  — all flat, PJRT-friendly.
    """
    loss, grads = jax.value_and_grad(lambda p: lm_loss(cfg, p, tokens_plus))(params)
    b1, b2, eps = 0.9, 0.999, 1e-8
    step = step + 1
    fstep = step.astype(jnp.float32)
    m = b1 * m + (1.0 - b1) * grads
    v = b2 * v + (1.0 - b2) * jnp.square(grads)
    mhat = m / (1.0 - b1**fstep)
    vhat = v / (1.0 - b2**fstep)
    params = params - lr * mhat / (jnp.sqrt(vhat) + eps)
    return params, m, v, step, loss


def logit_match_grad(cfg: ModelConfig, params, tokens, teacher_logits):
    """Loss + flat-weight gradient of the end-to-end objective (Alg. 2):
    L = mean((student_logits − teacher_logits)²).

    Rust maps the weight gradient back to per-axis scale gradients via the
    delta chain rule (dL/dv_j = Σ_i dL/dW[j,i] · B[j,i], etc.).
    """

    def loss_fn(p):
        logits = forward(cfg, p, tokens)
        return jnp.mean(jnp.square(logits - teacher_logits))

    loss, grads = jax.value_and_grad(loss_fn)(params)
    return loss, grads


def init_params(cfg: ModelConfig, seed: int) -> jnp.ndarray:
    """Scaled-normal init (distributionally equal to the Rust init; parity
    fixtures ship concrete params across the boundary, not seeds)."""
    lay = layout_offsets(cfg)
    key = jax.random.PRNGKey(seed)
    parts = []
    d, f, v = cfg.dim, cfg.ff, cfg.vocab
    std_d = 1.0 / float(d) ** 0.5
    std_f = 1.0 / float(f) ** 0.5

    def nrm(key, n, std):
        return jax.random.normal(key, (n,), dtype=jnp.float32) * std

    key, k = jax.random.split(key)
    parts.append(nrm(k, v * d, 0.02))
    for _ in range(cfg.n_layers):
        key, k1, k2, k3, k4, k5, k6, k7 = jax.random.split(key, 8)
        parts.append(jnp.ones((d,), jnp.float32))
        parts.append(nrm(k1, d * d, std_d))
        parts.append(nrm(k2, d * d, std_d))
        parts.append(nrm(k3, d * d, std_d))
        parts.append(nrm(k4, d * d, std_d))
        parts.append(jnp.ones((d,), jnp.float32))
        parts.append(nrm(k5, f * d, std_d))
        parts.append(nrm(k6, f * d, std_d))
        parts.append(nrm(k7, d * f, std_f))
    key, k = jax.random.split(key)
    parts.append(jnp.ones((d,), jnp.float32))
    parts.append(nrm(k, v * d, std_d))
    flat = jnp.concatenate(parts)
    assert flat.shape[0] == lay["total"]
    return flat


def jit_forward(cfg: ModelConfig):
    return jax.jit(partial(forward, cfg))


def jit_train_step(cfg: ModelConfig):
    return jax.jit(partial(train_step, cfg))


def jit_logit_match_grad(cfg: ModelConfig):
    return jax.jit(partial(logit_match_grad, cfg))
