"""L1 Pallas kernel: packed-sign per-axis delta apply.

Computes ``Ŵ = W_b + v ⊙ B`` where B arrives *packed* (u32 words, 1 bit per
entry along the input axis) and is expanded in-kernel — the packed tile is
32× smaller than the dense tile, so HBM→VMEM traffic is dominated by the
base weights alone (the paper's "masks stay packed end-to-end").

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid walks row blocks;
each step streams one `(block_rows, d_in)` base tile into VMEM together
with its `(block_rows, d_in/32)` packed words and the scale block, expands
bits with shift/AND on the VPU, and writes one output tile. Double
buffering comes from the Pallas pipeline. `interpret=True` everywhere on
this CPU image (real-TPU lowering emits Mosaic custom-calls the CPU PJRT
plugin cannot execute).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import words_per_row


def _pick_block(n: int, cap: int = 128) -> int:
    """Largest power-of-two-ish divisor of n, at most cap."""
    for b in (128, 64, 32, 16, 8, 4, 2, 1):
        if b <= cap and n % b == 0:
            return b
    return 1


def _expand_signs(packed_tile, d_in: int):
    """[bo, wpr] u32 -> ±1.0 f32 [bo, d_in] (in-kernel bit expansion)."""
    wpr = packed_tile.shape[-1]
    i = jnp.arange(wpr * 32, dtype=jnp.uint32)
    word_idx = (i // 32).astype(jnp.int32)
    bit_idx = i % 32
    bits = (packed_tile[:, word_idx] >> bit_idx[None, :]) & jnp.uint32(1)
    return (bits.astype(jnp.float32) * 2.0 - 1.0)[:, :d_in]


def _kernel_row(base_ref, packed_ref, scales_ref, out_ref, *, d_in):
    signs = _expand_signs(packed_ref[...], d_in)
    out_ref[...] = base_ref[...] + scales_ref[...][:, None] * signs


def _kernel_col(base_ref, packed_ref, scales_ref, out_ref, *, d_in):
    signs = _expand_signs(packed_ref[...], d_in)
    out_ref[...] = base_ref[...] + scales_ref[...][None, :] * signs


@functools.partial(jax.jit, static_argnames=("axis", "block_rows"))
def delta_apply(base, packed, scales, *, axis: str, block_rows: int | None = None):
    """Pallas delta apply. base [d_out, d_in] f32, packed [d_out, wpr] u32,
    scales [d_out] (row) or [d_in] (col) f32 -> Ŵ [d_out, d_in] f32."""
    d_out, d_in = base.shape
    wpr = words_per_row(d_in)
    assert packed.shape == (d_out, wpr), (packed.shape, (d_out, wpr))
    bo = block_rows or _pick_block(d_out)
    assert d_out % bo == 0, f"block_rows {bo} must divide d_out {d_out}"
    grid = (d_out // bo,)
    if axis == "row":
        assert scales.shape == (d_out,)
        kernel = functools.partial(_kernel_row, d_in=d_in)
        scale_spec = pl.BlockSpec((bo,), lambda i: (i,))
    elif axis == "col":
        assert scales.shape == (d_in,)
        kernel = functools.partial(_kernel_col, d_in=d_in)
        scale_spec = pl.BlockSpec((d_in,), lambda i: (0,))
    else:
        raise ValueError(f"bad axis {axis}")
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bo, d_in), lambda i: (i, 0)),
            pl.BlockSpec((bo, wpr), lambda i: (i, 0)),
            scale_spec,
        ],
        out_specs=pl.BlockSpec((bo, d_in), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((d_out, d_in), jnp.float32),
        interpret=True,  # CPU image: Mosaic lowering unavailable
    )(base, packed, scales)


def vmem_bytes_per_step(d_out: int, d_in: int, block_rows: int | None = None) -> int:
    """Structural VMEM footprint estimate for one grid step (perf model for
    DESIGN.md §Perf: base tile + out tile + packed tile + scale block)."""
    bo = block_rows or _pick_block(d_out)
    wpr = words_per_row(d_in)
    base = bo * d_in * 4
    out = bo * d_in * 4
    packed = bo * wpr * 4
    scales = max(bo, d_in) * 4
    return base + out + packed + scales
