"""L1 Pallas kernel: fused delta-GEMM.

``y = x · (W_b + v ⊙ B)ᵀ`` computed without materializing Ŵ in HBM — the
paper's §4 "on-the-fly variant ... would introduce runtime overhead unless
supported by fused GEMM kernels", implemented. Each grid step reconstructs
one weight tile in VMEM (base tile + in-register sign expansion + broadcast
scale) and feeds it straight into the MXU contraction, so the only HBM
traffic beyond a plain GEMM is the packed mask at 1/32 of the dense bytes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .delta_apply import _expand_signs, _pick_block
from .ref import words_per_row


def _kernel(x_ref, base_ref, packed_ref, scales_ref, out_ref, *, d_in, axis):
    signs = _expand_signs(packed_ref[...], d_in)
    if axis == "row":
        w = base_ref[...] + scales_ref[...][:, None] * signs
    else:
        w = base_ref[...] + scales_ref[...][None, :] * signs
    # One MXU contraction per tile; f32 accumulation.
    out_ref[...] = jnp.dot(x_ref[...], w.T, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("axis", "block_n", "block_m"))
def fused_delta_matmul(
    x, base, packed, scales, *, axis: str, block_n: int | None = None, block_m: int | None = None
):
    """x [n, d_in] f32, base [d_out, d_in] f32, packed [d_out, wpr] u32,
    scales [d_out]|[d_in] f32 -> y [n, d_out] f32."""
    n, d_in = x.shape
    d_out, _ = base.shape
    wpr = words_per_row(d_in)
    assert packed.shape == (d_out, wpr)
    bn = block_n or _pick_block(n, 64)
    bm = block_m or _pick_block(d_out, 128)
    assert n % bn == 0 and d_out % bm == 0
    grid = (n // bn, d_out // bm)
    if axis == "row":
        assert scales.shape == (d_out,)
        scale_spec = pl.BlockSpec((bm,), lambda i, j: (j,))
    elif axis == "col":
        assert scales.shape == (d_in,)
        scale_spec = pl.BlockSpec((d_in,), lambda i, j: (0,))
    else:
        raise ValueError(f"bad axis {axis}")
    kernel = functools.partial(_kernel, d_in=d_in, axis=axis)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d_in), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, d_in), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, wpr), lambda i, j: (j, 0)),
            scale_spec,
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, d_out), jnp.float32),
        interpret=True,  # CPU image: Mosaic lowering unavailable
    )(x, base, packed, scales)


def mxu_utilization_estimate(n: int, d_out: int, d_in: int) -> float:
    """Structural MXU-utilization estimate (DESIGN.md §Perf): fraction of a
    128×128 systolic tile kept busy by the chosen blocks, discounted by the
    VPU sign-expansion overhead (~d_in ops per 256·d_in MACs at bm=128,
    bn=64 — negligible)."""
    bn = _pick_block(n, 64)
    bm = _pick_block(d_out, 128)
    fill = (min(bn, 128) / 128.0) * (min(bm, 128) / 128.0)
    expand_overhead = 1.0 / (2.0 * min(bn, 128))
    return fill * (1.0 - expand_overhead)
