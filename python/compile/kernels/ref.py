"""Pure-jnp oracles for the Pallas kernels.

These are the correctness reference for:
* sign extraction + bit packing (1 bit along the input axis, u32 words,
  bit i of word w = sign of column 32·w + i; 1 -> +1, 0 -> -1; ties at 0
  map to +1 — matching ``rust/src/delta/pack.rs``),
* the per-axis delta apply ``Ŵ = W_b + v ⊙ B``,
* the fused delta-GEMM ``y = x · (W_b + v ⊙ B)ᵀ``.
"""

from __future__ import annotations

import jax.numpy as jnp


def words_per_row(d_in: int) -> int:
    return (d_in + 31) // 32


def pack_signs(delta: jnp.ndarray) -> jnp.ndarray:
    """delta: [d_out, d_in] f32 -> packed [d_out, ceil(d_in/32)] uint32."""
    d_out, d_in = delta.shape
    wpr = words_per_row(d_in)
    bits = (delta >= 0).astype(jnp.uint32)  # sign(0) -> +1
    pad = wpr * 32 - d_in
    if pad:
        bits = jnp.pad(bits, ((0, 0), (0, pad)))
    bits = bits.reshape(d_out, wpr, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(bits << shifts[None, None, :], axis=-1, dtype=jnp.uint32)


def unpack_signs(packed: jnp.ndarray, d_in: int) -> jnp.ndarray:
    """packed: [d_out, wpr] uint32 -> ±1.0 f32 [d_out, d_in]."""
    d_out, wpr = packed.shape
    assert wpr == words_per_row(d_in)
    i = jnp.arange(wpr * 32, dtype=jnp.uint32)
    word_idx = (i // 32).astype(jnp.int32)
    bit_idx = i % 32
    bits = (packed[:, word_idx] >> bit_idx[None, :]) & 1
    signs = bits.astype(jnp.float32) * 2.0 - 1.0
    return signs[:, :d_in]


def delta_apply_ref(base, packed, scales, axis: str):
    """Ŵ = W_b + v ⊙ B. axis ∈ {row, col}; scales [d_out] or [d_in]."""
    d_out, d_in = base.shape
    signs = unpack_signs(packed, d_in)
    if axis == "row":
        assert scales.shape == (d_out,)
        return base + scales[:, None] * signs
    elif axis == "col":
        assert scales.shape == (d_in,)
        return base + scales[None, :] * signs
    raise ValueError(f"bad axis {axis}")


def fused_delta_matmul_ref(x, base, packed, scales, axis: str):
    """y = x · (W_b + v ⊙ B)ᵀ without the caller materializing Ŵ."""
    w = delta_apply_ref(base, packed, scales, axis)
    return x @ w.T
