"""AOT export: lower every program the Rust runtime needs to HLO *text* and
write a manifest describing shapes/dtypes/argument order.

HLO text (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md). All programs are lowered with
``return_tuple=True`` so the Rust side unwraps one tuple per execution.

Exported program families (see artifacts/manifest.json):

* ``fwd_{cfg}_b{B}_t{T}``      — (params f32[P], tokens i32[B,T]) -> logits
* ``train_{cfg}_b{B}_t{T}``    — fused AdamW LM step
* ``lmgrad_{cfg}_b{B}_t{T}``   — logit-matching loss + flat grad (Alg. 2)
* ``dapply_{axis}_{O}x{I}``    — Pallas delta apply for a weight shape
* ``dmm_{axis}_n{N}_{O}x{I}``  — Pallas fused delta-GEMM

Run ``python -m compile.aot --out-dir ../artifacts``; it is incremental-
friendly (the Makefile only invokes it when compile/ sources change).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.delta_apply import delta_apply
from .kernels.fused_matmul import fused_delta_matmul
from .kernels.ref import words_per_row

# Shape buckets exported per config. Kept deliberately small: each entry is
# one more XLA program the runtime compiles at startup.
FWD_BUCKETS = {
    "tiny": [(1, 48), (4, 48), (8, 48)],
    "llama-mini": [(1, 96), (4, 96), (8, 96)],
    "qwen-mini": [(1, 96), (4, 96)],
    "phi-mini": [(1, 96), (4, 96)],
    "base-110m": [(1, 128)],
}
TRAIN_BUCKETS = {
    "tiny": (8, 48),
    "llama-mini": (8, 96),
    "qwen-mini": (8, 96),
    "phi-mini": (8, 96),
    "base-110m": (4, 128),
}
# lmgrad batches are small (150 calibration docs streamed in chunks).
LMGRAD_BUCKETS = {
    "tiny": (4, 48),
    "llama-mini": (4, 96),
    "qwen-mini": (4, 96),
    "phi-mini": (4, 96),
    "base-110m": (2, 128),
}
# Kernel artifact shapes: the patchable weight shapes of these configs.
KERNEL_CONFIGS = ["tiny", "llama-mini"]
FUSED_N = 64  # token rows per fused-GEMM artifact


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def dtype_name(dt) -> str:
    return {jnp.float32: "f32", jnp.int32: "i32", jnp.uint32: "u32"}[dt]


class Exporter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.manifest = {"programs": {}, "configs": {}}
        os.makedirs(out_dir, exist_ok=True)

    def add(self, name: str, fn, in_specs, meta=None):
        """Lower fn at in_specs, write HLO text, record manifest entry."""
        lowered = jax.jit(fn).lower(*[spec(s, d) for (s, d) in in_specs])
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        out_tree = lowered.out_info
        outs = []

        def walk(x):
            outs.append({"shape": list(x.shape), "dtype": str(x.dtype)})

        jax.tree_util.tree_map(walk, out_tree)
        self.manifest["programs"][name] = {
            "file": fname,
            "inputs": [{"shape": list(s), "dtype": dtype_name(d)} for (s, d) in in_specs],
            "outputs": outs,
            "meta": meta or {},
        }
        print(f"  wrote {fname} ({len(text) / 1024:.0f} KiB)")

    def save_manifest(self):
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump(self.manifest, f, indent=1, sort_keys=True)
        print(f"  wrote manifest.json ({len(self.manifest['programs'])} programs)")


def patchable_shapes(cfg: M.ModelConfig):
    d, f = cfg.dim, cfg.ff
    return sorted({(d, d), (f, d), (d, f)})


def export_config(ex: Exporter, cfg: M.ModelConfig):
    P = cfg.n_params()
    ex.manifest["configs"][cfg.name] = {
        "vocab": cfg.vocab,
        "dim": cfg.dim,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "ff": cfg.ff,
        "max_seq": cfg.max_seq,
        "n_params": P,
    }
    f32, i32 = jnp.float32, jnp.int32
    for (b, t) in FWD_BUCKETS[cfg.name]:
        ex.add(
            f"fwd_{cfg.name}_b{b}_t{t}",
            lambda p, tok, cfg=cfg: (M.forward(cfg, p, tok),),
            [((P,), f32), ((b, t), i32)],
            meta={"kind": "forward", "config": cfg.name, "batch": b, "seq": t},
        )
    (b, t) = TRAIN_BUCKETS[cfg.name]
    ex.add(
        f"train_{cfg.name}_b{b}_t{t}",
        lambda p, m, v, s, lr, tok, cfg=cfg: M.train_step(cfg, p, m, v, s, lr, tok),
        [((P,), f32), ((P,), f32), ((P,), f32), ((), i32), ((), f32), ((b, t + 1), i32)],
        meta={"kind": "train_step", "config": cfg.name, "batch": b, "seq": t},
    )
    (b, t) = LMGRAD_BUCKETS[cfg.name]
    ex.add(
        f"lmgrad_{cfg.name}_b{b}_t{t}",
        lambda p, tok, tl, cfg=cfg: M.logit_match_grad(cfg, p, tok, tl),
        [((P,), f32), ((b, t), i32), ((b, t, cfg.vocab), f32)],
        meta={"kind": "lmgrad", "config": cfg.name, "batch": b, "seq": t},
    )


def export_kernels(ex: Exporter, cfg: M.ModelConfig):
    f32, u32 = jnp.float32, jnp.uint32
    for (d_out, d_in) in patchable_shapes(cfg):
        wpr = words_per_row(d_in)
        for axis in ("row", "col"):
            ns = d_out if axis == "row" else d_in
            ex.add(
                f"dapply_{axis}_{d_out}x{d_in}",
                lambda base, packed, scales, axis=axis: (
                    delta_apply(base, packed, scales, axis=axis),
                ),
                [((d_out, d_in), f32), ((d_out, wpr), u32), ((ns,), f32)],
                meta={"kind": "delta_apply", "axis": axis, "d_out": d_out, "d_in": d_in},
            )
            ex.add(
                f"dmm_{axis}_n{FUSED_N}_{d_out}x{d_in}",
                lambda x, base, packed, scales, axis=axis: (
                    fused_delta_matmul(x, base, packed, scales, axis=axis),
                ),
                [((FUSED_N, d_in), f32), ((d_out, d_in), f32), ((d_out, wpr), u32), ((ns,), f32)],
                meta={
                    "kind": "fused_delta_matmul",
                    "axis": axis,
                    "n": FUSED_N,
                    "d_out": d_out,
                    "d_in": d_in,
                },
            )


def export_parity_fixture(ex: Exporter, cfg: M.ModelConfig, b: int, t: int):
    """Golden cross-language fixture: concrete params + tokens + the jax
    logits, consumed by rust/tests/integration_runtime.rs to check that the
    native Rust forward, the jax forward, and the PJRT-executed artifact all
    agree. Binary little-endian layout:
    u32 P | f32×P params | u32 B | u32 T | i32×(B·T) tokens |
    u32 V | f32×(B·T·V) logits."""
    import numpy as np

    params = np.asarray(M.init_params(cfg, 12345), np.float32)
    rng = np.random.default_rng(777)
    tokens = rng.integers(0, cfg.vocab, size=(b, t)).astype(np.int32)
    logits = np.asarray(M.jit_forward(cfg)(jnp.asarray(params), jnp.asarray(tokens)), np.float32)
    path = os.path.join(ex.out_dir, f"parity_{cfg.name}.bin")
    with open(path, "wb") as f:
        f.write(np.uint32(params.size).tobytes())
        f.write(params.tobytes())
        f.write(np.uint32(b).tobytes())
        f.write(np.uint32(t).tobytes())
        f.write(tokens.tobytes())
        f.write(np.uint32(cfg.vocab).tobytes())
        f.write(logits.tobytes())
    ex.manifest["programs"][f"parity_{cfg.name}"] = {
        "file": f"parity_{cfg.name}.bin",
        "inputs": [],
        "outputs": [],
        "meta": {"kind": "parity_fixture", "config": cfg.name, "batch": b, "seq": t},
    }
    print(f"  wrote parity_{cfg.name}.bin")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--configs",
        default="tiny,llama-mini,qwen-mini,phi-mini",
        help="comma-separated config presets to export (base-110m on demand)",
    )
    args = ap.parse_args(argv)
    ex = Exporter(args.out_dir)
    names = [c for c in args.configs.split(",") if c]
    for name in names:
        cfg = M.PRESETS[name]
        print(f"[aot] exporting {name} (P={cfg.n_params() / 1e6:.2f}M)")
        export_config(ex, cfg)
        if name in KERNEL_CONFIGS:
            export_kernels(ex, cfg)
        if name == "tiny":
            b, t = FWD_BUCKETS["tiny"][1]
            export_parity_fixture(ex, cfg, b, t)
    ex.save_manifest()
    print("[aot] done")


if __name__ == "__main__":
    sys.exit(main())
